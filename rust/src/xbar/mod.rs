//! The non-coherent IO crossbar with thread-safe layers (paper §4.3) and
//! the deterministic border-staged layer arbitration (docs/XBAR.md).
//!
//! An N-to-M crossbar: each *layer* is a channel to one target that only one
//! initiator may hold at a time. Initiators occupy the layer, talk to the
//! target with the classic timing protocol, and release it when the response
//! returns; rejected initiators are woken with a retry.
//!
//! Two arbitration contracts ([`crate::sched::XbarArb`]):
//!
//! * **Host** (the paper's §4.3): the layer state sits behind a mutex and
//!   [`XbarState::try_occupy`] uses `try_lock` — initiators racing on
//!   *host* time (their local simulated times may differ!) are simply
//!   rejected and retry, which the paper shows is a special case of the
//!   existing occupy/retry protocol. Which initiator wins is host-timing
//!   dependent — the last documented nondeterminism of the threaded
//!   kernel.
//! * **Border** (the default): layer requests are *staged* per sender
//!   domain during the window ([`XbarState::stage_occupy`], mirroring
//!   `ruby::inbox::Inbox::stage`) and granted at the quantum border —
//!   inside the quiescent span, by [`XbarState::border_grants`] via the
//!   [`arbiter::XbarArbiter`] component — in canonical
//!   `(request_tick, sender_domain, seq)` order. Busy outcomes stay
//!   queued per layer and replay as postponed grants at later borders, so
//!   occupancy, delivery ticks and every statistic are a pure function of
//!   the simulation (docs/DETERMINISM.md).
//!
//! gem5's IO-XBAR is a SimObject; here the crossbar is the shared layer
//! state plus direct event scheduling into the target's domain (semantics
//! identical; the crossing latency is charged on the scheduled delivery).

pub mod arbiter;

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::{Arc, Mutex};

use crate::ckpt::io::{CkptError, StateReader, StateWriter};
use crate::proto::Packet;
use crate::sim::ids::CompId;
use crate::sim::shared::PdesStats;
use crate::sim::stats::StatSink;
use crate::sim::time::{Tick, NS};

pub use arbiter::XbarArbiter;

/// One layer: the channel to a single target.
#[derive(Default)]
struct Layer {
    occupied_by: Option<CompId>,
    waiting: Vec<CompId>,
}

/// One staged layer request of the border-staged arbitration protocol:
/// the canonical key `(req_tick, sender_dom, seq)` plus the packet to
/// deliver when the grant happens.
#[derive(Clone, Copy, Debug)]
struct StagedReq {
    req_tick: Tick,
    sender_dom: u32,
    seq: u64,
    layer: usize,
    who: CompId,
    pkt: Packet,
}

/// Border-staged arbitration state, all behind one mutex: the current
/// window's stage (host append order, canonicalised at the border) and the
/// per-layer queues of requests still waiting for a grant.
#[derive(Default)]
struct ArbState {
    stage: Vec<StagedReq>,
    /// Per-sender-domain staging sequence counters for the current window
    /// (tiny linear-scan map `domain → next seq`, like the inbox's).
    stage_seqs: Vec<(u32, u64)>,
    /// Per-layer pending requests in canonical order, head = oldest.
    pending: Vec<VecDeque<StagedReq>>,
}

/// One border grant decision: deliver `pkt` to the layer's target at tick
/// `deliver` (the grant also marked the layer occupied by the requester).
#[derive(Debug)]
pub struct Grant {
    /// The device the granted request must be delivered to.
    pub target: CompId,
    /// Delivery tick: `max(req_tick + latency, border)` — the same
    /// postponement convention as the cross-domain injector path.
    pub deliver: Tick,
    /// The granted request's packet.
    pub pkt: Packet,
}

/// Address range → target mapping entry.
#[derive(Clone, Copy, Debug)]
pub struct XbarTarget {
    pub base: u64,
    pub size: u64,
    pub comp: CompId,
}

pub struct XbarState {
    targets: Vec<XbarTarget>,
    layers: Vec<Mutex<Layer>>,
    /// Border-staged arbitration state (inert under `--xbar-arb host`).
    arb: Mutex<ArbState>,
    /// Requests the next border arbitration must look at: the window's
    /// stagings plus every carried-over pending queue entry. Lets
    /// [`XbarState::has_border_work`] answer the IO-free-border question
    /// with one relaxed load, so the per-domain arbiter hook skips the
    /// `arb` lock entirely on the (overwhelmingly common) borders with no
    /// IO traffic. Senders only increment mid-window; the exact value is
    /// re-established by `border_grants` inside the quiescent span.
    border_work: AtomicU64,
    /// Crossbar traversal latency (request and response each).
    pub latency: Tick,
    /// Retry backoff after a host-time mutex collision.
    pub retry_delay: Tick,
    // stats
    pub occupancies: AtomicU64,
    pub busy_rejects: AtomicU64,
    pub lock_rejects: AtomicU64,
}

/// Outcome of an occupancy attempt.
#[derive(Debug, PartialEq, Eq)]
pub enum Occupy {
    /// Layer acquired; deliver the request to `target`.
    Granted { target: CompId },
    /// Layer held by another initiator; a retry event will come.
    Busy,
    /// Host-time mutex collision (§4.3); retry after `retry_delay`.
    Contended,
    /// Address matches no target.
    NoTarget,
}

impl XbarState {
    pub fn new(targets: Vec<XbarTarget>, latency: Tick, retry_delay: Tick) -> Arc<Self> {
        let layers = (0..targets.len()).map(|_| Mutex::new(Layer::default())).collect();
        let pending = (0..targets.len()).map(|_| VecDeque::new()).collect();
        Arc::new(XbarState {
            targets,
            layers,
            arb: Mutex::new(ArbState {
                stage: Vec::new(),
                stage_seqs: Vec::new(),
                pending,
            }),
            border_work: AtomicU64::new(0),
            latency,
            retry_delay,
            occupancies: AtomicU64::new(0),
            busy_rejects: AtomicU64::new(0),
            lock_rejects: AtomicU64::new(0),
        })
    }

    /// Number of layers (= targets) in this crossbar.
    pub fn n_layers(&self) -> usize {
        self.targets.len()
    }

    /// Index of the layer serving `addr`.
    pub fn layer_of(&self, addr: u64) -> Option<usize> {
        self.targets
            .iter()
            .position(|t| addr >= t.base && addr < t.base + t.size)
    }

    /// Try to occupy the layer for `addr` on behalf of `who`.
    pub fn try_occupy(&self, addr: u64, who: CompId) -> Occupy {
        let Some(idx) = self.layer_of(addr) else {
            return Occupy::NoTarget;
        };
        match self.layers[idx].try_lock() {
            Err(_) => {
                // Another domain thread holds the layer mutex *right now*:
                // treat as a transient rejection (paper §4.3).
                self.lock_rejects.fetch_add(1, Relaxed);
                Occupy::Contended
            }
            Ok(mut layer) => {
                if layer.occupied_by.is_some() {
                    self.busy_rejects.fetch_add(1, Relaxed);
                    if !layer.waiting.contains(&who) {
                        layer.waiting.push(who);
                    }
                    Occupy::Busy
                } else {
                    layer.occupied_by = Some(who);
                    self.occupancies.fetch_add(1, Relaxed);
                    Occupy::Granted { target: self.targets[idx].comp }
                }
            }
        }
    }

    /// Release the layer for `addr`; returns the next waiting initiator (to
    /// be sent a retry event), if any.
    ///
    /// Under the border-staged arbitration nothing ever enters the
    /// host-mode wait list, so the release only clears the occupancy
    /// (always `None`); the freed layer is re-granted to the head of the
    /// canonical pending queue at the *next* border
    /// ([`XbarState::border_grants`]). A mid-window release is safe under
    /// true concurrency because border mode never reads layer state
    /// mid-window — only the holder's own thread writes it, and the
    /// arbiter reads it strictly after the freeze barrier.
    pub fn release(&self, addr: u64, who: CompId) -> Option<CompId> {
        let idx = self.layer_of(addr)?;
        let mut layer = self.layers[idx].lock().unwrap();
        debug_assert_eq!(layer.occupied_by, Some(who), "release by non-holder");
        layer.occupied_by = None;
        if layer.waiting.is_empty() {
            None
        } else {
            Some(layer.waiting.remove(0))
        }
    }

    /// Border-staged arbitration (`--xbar-arb border`): stage a layer
    /// request for `pkt.addr` on behalf of `who` (domain `sender_dom`) at
    /// simulated time `req_tick`, to be arbitrated at the next quantum
    /// border in canonical `(req_tick, sender_dom, seq)` order.
    ///
    /// `seq` is this sender domain's program order within the window —
    /// well-defined under work stealing because a window claim hands each
    /// domain to exactly one thread. Mid-window this touches *only* the
    /// staging state, never the layers, so nothing an arbitration decision
    /// depends on is written in host-timing order (docs/XBAR.md).
    ///
    /// Returns `false` (staging nothing) when `pkt.addr` maps to no
    /// target, mirroring [`Occupy::NoTarget`].
    #[must_use]
    pub fn stage_occupy(
        &self,
        sender_dom: u32,
        who: CompId,
        req_tick: Tick,
        pkt: Packet,
        stats: &PdesStats,
    ) -> bool {
        let Some(layer) = self.layer_of(pkt.addr) else {
            return false;
        };
        let mut arb = self.arb.lock().unwrap();
        let seq = match arb
            .stage_seqs
            .iter_mut()
            .find(|(d, _)| *d == sender_dom)
        {
            Some((_, next)) => {
                let s = *next;
                *next += 1;
                s
            }
            None => {
                arb.stage_seqs.push((sender_dom, 1));
                0
            }
        };
        arb.stage.push(StagedReq { req_tick, sender_dom, seq, layer, who, pkt });
        self.border_work.fetch_add(1, Relaxed);
        stats.xbar_staged.fetch_add(1, Relaxed);
        true
    }

    /// Whether the next border arbitration has anything to decide (staged
    /// requests or carried-over pending grants). One relaxed load — the
    /// IO-free-border fast path checked by
    /// [`arbiter::XbarArbiter::border_merge`] before taking any lock.
    /// Exact inside the quiescent span (senders are parked).
    pub fn has_border_work(&self) -> bool {
        self.border_work.load(Relaxed) != 0
    }

    /// Layer requests currently staged for the next border arbitration.
    pub fn staged_len(&self) -> usize {
        self.arb.lock().unwrap().stage.len()
    }

    /// Requests pending a grant on `layer` (staged at earlier borders,
    /// still waiting for the layer to free up).
    pub fn pending_len(&self, layer: usize) -> usize {
        self.arb.lock().unwrap().pending[layer].len()
    }

    /// The border arbitration (the heart of `--xbar-arb border`): sort the
    /// window's staged requests into canonical
    /// `(req_tick, sender_dom, seq)` order, append them to the per-layer
    /// pending queues, and grant each *free* layer to the head of its
    /// queue — marking the layer occupied and returning the grant so the
    /// caller (the [`XbarArbiter`] component, which lives in the same
    /// domain as every crossbar target) can schedule the `MemReq`
    /// delivery at `max(req_tick + latency, border)`. Occupied layers
    /// defer their whole queue to a later border
    /// (`PdesStats::xbar_deferred_grants`); deliveries clamped to the
    /// border are accounted as postponement (`postponed` / `tpp_sum`),
    /// exactly like the inbox merge.
    ///
    /// Must only be called at a quantum border inside the quiescent span
    /// (every producer parked at the freeze barrier), once per border: the
    /// stage content is frozen and every release of the closed window has
    /// happened, so the outcome is a pure function of the simulation.
    pub fn border_grants(&self, border: Tick, stats: &PdesStats) -> Vec<Grant> {
        let mut arb = self.arb.lock().unwrap();
        let ArbState { stage, stage_seqs, pending } = &mut *arb;
        if !stage.is_empty() {
            let mut staged = std::mem::take(stage);
            stage_seqs.clear();
            // Unstable sort is deterministic here: the key is unique
            // (per-domain seqs never repeat within a window).
            staged.sort_unstable_by_key(|s| (s.req_tick, s.sender_dom, s.seq));
            for s in staged {
                pending[s.layer].push_back(s);
            }
        }
        let mut grants = Vec::new();
        let mut deferred = 0u64;
        for (li, queue) in pending.iter_mut().enumerate() {
            if queue.is_empty() {
                continue;
            }
            let mut layer = self.layers[li].lock().unwrap();
            if layer.occupied_by.is_some() {
                deferred += queue.len() as u64;
                continue;
            }
            let s = queue.pop_front().expect("checked non-empty");
            layer.occupied_by = Some(s.who);
            self.occupancies.fetch_add(1, Relaxed);
            // One grant per layer per border: the rest of the queue waits
            // for the release (and the next border).
            deferred += queue.len() as u64;
            let arrive = s.req_tick + self.latency;
            let deliver = arrive.max(border);
            if deliver > arrive {
                stats.postponed.fetch_add(1, Relaxed);
                stats.tpp_sum.fetch_add(deliver - arrive, Relaxed);
            }
            grants.push(Grant {
                target: self.targets[s.layer].comp,
                deliver,
                pkt: s.pkt,
            });
        }
        stats.xbar_deferred_grants.fetch_add(deferred, Relaxed);
        // Re-establish the fast-path counter: exactly the carried-over
        // pending entries survive this border (the quiescent span keeps
        // senders parked, so no increment races this store).
        let remaining: u64 = pending.iter().map(|q| q.len() as u64).sum();
        self.border_work.store(remaining, Relaxed);
        grants
    }

    pub fn stats(&self, out: &mut StatSink) {
        out.add_u64("occupancies", self.occupancies.load(Relaxed));
        out.add_u64("busy_rejects", self.busy_rejects.load(Relaxed));
        out.add_u64("lock_rejects", self.lock_rejects.load(Relaxed));
    }

    /// Checkpoint producer half, called at a quantum border inside the
    /// quiescent span (strictly after [`XbarState::border_grants`] ran for
    /// that border): the window stage is empty by construction — only the
    /// layer occupancies, host-mode wait lists and carried-over pending
    /// queues are architectural.
    pub fn save_ckpt(&self, w: &mut StateWriter) {
        let arb = self.arb.lock().unwrap();
        assert!(
            arb.stage.is_empty() && arb.stage_seqs.is_empty(),
            "xbar checkpoint outside the quiescent span: staged requests present"
        );
        w.usize(self.layers.len());
        for layer in &self.layers {
            let l = layer.lock().unwrap();
            w.opt_comp_id(l.occupied_by);
            w.usize(l.waiting.len());
            for &c in &l.waiting {
                w.comp_id(c);
            }
        }
        for q in &arb.pending {
            w.usize(q.len());
            for s in q {
                w.u64(s.req_tick);
                w.u32(s.sender_dom);
                w.u64(s.seq);
                w.usize(s.layer);
                w.comp_id(s.who);
                w.packet(&s.pkt);
            }
        }
        w.u64(self.occupancies.load(Relaxed));
        w.u64(self.busy_rejects.load(Relaxed));
        w.u64(self.lock_rejects.load(Relaxed));
    }

    /// Checkpoint restore half for a freshly built crossbar of the same
    /// topology.
    pub fn restore_ckpt(&self, r: &mut StateReader) -> Result<(), CkptError> {
        let n = r.usize()?;
        if n != self.layers.len() {
            return Err(CkptError::Mismatch {
                what: "xbar layer count".to_string(),
                expected: self.layers.len().to_string(),
                found: n.to_string(),
            });
        }
        for layer in &self.layers {
            let mut l = layer.lock().unwrap();
            l.occupied_by = r.opt_comp_id()?;
            l.waiting.clear();
            for _ in 0..r.usize()? {
                l.waiting.push(r.comp_id()?);
            }
        }
        let mut arb = self.arb.lock().unwrap();
        let mut work = 0u64;
        for q in arb.pending.iter_mut() {
            q.clear();
            for _ in 0..r.usize()? {
                let req_tick = r.u64()?;
                let sender_dom = r.u32()?;
                let seq = r.u64()?;
                let layer = r.usize()?;
                let who = r.comp_id()?;
                let pkt = r.packet()?;
                q.push_back(StagedReq {
                    req_tick,
                    sender_dom,
                    seq,
                    layer,
                    who,
                    pkt,
                });
                work += 1;
            }
        }
        self.border_work.store(work, Relaxed);
        self.occupancies.store(r.u64()?, Relaxed);
        self.busy_rejects.store(r.u64()?, Relaxed);
        self.lock_rejects.store(r.u64()?, Relaxed);
        Ok(())
    }
}

/// Default IO region layout: IO space starts at 256 GiB, each device gets a
/// 4 KiB page.
pub const IO_BASE: u64 = 0x40_0000_0000;
pub const IO_PAGE: u64 = 0x1000;

pub fn default_xbar(device_comps: &[CompId]) -> Arc<XbarState> {
    let targets = device_comps
        .iter()
        .enumerate()
        .map(|(i, &comp)| XbarTarget {
            base: IO_BASE + i as u64 * IO_PAGE,
            size: IO_PAGE,
            comp,
        })
        .collect();
    XbarState::new(targets, 5 * NS, NS)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn xbar2() -> Arc<XbarState> {
        default_xbar(&[CompId(10), CompId(11)])
    }

    #[test]
    fn grant_then_busy_then_release_retry() {
        let x = xbar2();
        let a = CompId(1);
        let b = CompId(2);
        assert_eq!(
            x.try_occupy(IO_BASE, a),
            Occupy::Granted { target: CompId(10) }
        );
        assert_eq!(x.try_occupy(IO_BASE, b), Occupy::Busy);
        assert_eq!(x.release(IO_BASE, a), Some(b));
        // b was popped from the wait list; now b can occupy
        assert_eq!(
            x.try_occupy(IO_BASE, b),
            Occupy::Granted { target: CompId(10) }
        );
        assert_eq!(x.release(IO_BASE, b), None);
    }

    #[test]
    fn disjoint_layers_are_independent() {
        let x = xbar2();
        assert!(matches!(
            x.try_occupy(IO_BASE, CompId(1)),
            Occupy::Granted { .. }
        ));
        assert!(matches!(
            x.try_occupy(IO_BASE + IO_PAGE, CompId(2)),
            Occupy::Granted { target } if target == CompId(11)
        ));
    }

    #[test]
    fn unmapped_address() {
        let x = xbar2();
        assert_eq!(x.try_occupy(0x1234, CompId(1)), Occupy::NoTarget);
    }

    #[test]
    fn no_duplicate_waiters() {
        let x = xbar2();
        x.try_occupy(IO_BASE, CompId(1));
        x.try_occupy(IO_BASE, CompId(2));
        x.try_occupy(IO_BASE, CompId(2));
        assert_eq!(x.release(IO_BASE, CompId(1)), Some(CompId(2)));
        assert_eq!(x.try_occupy(IO_BASE, CompId(2)), Occupy::Granted { target: CompId(10) });
        assert_eq!(x.release(IO_BASE, CompId(2)), None, "no stale waiter entry");
    }

    // ---- border-staged arbitration ----------------------------------

    use crate::proto::Cmd;

    /// Two-target crossbar with tick-granular latencies (latency 5,
    /// retry 1) so border arithmetic is readable in the tests below.
    fn xbar2b() -> Arc<XbarState> {
        XbarState::new(
            vec![
                XbarTarget { base: IO_BASE, size: IO_PAGE, comp: CompId(10) },
                XbarTarget {
                    base: IO_BASE + IO_PAGE,
                    size: IO_PAGE,
                    comp: CompId(11),
                },
            ],
            5,
            1,
        )
    }

    fn pkt(addr: u64, id: u64, requester: u32) -> Packet {
        Packet::request(id, Cmd::ReadReq, addr, 64, 0, CompId(requester), 0, 0)
    }

    fn stage(
        x: &XbarState,
        dom: u32,
        who: u32,
        tick: Tick,
        id: u64,
        stats: &PdesStats,
    ) {
        assert!(x.stage_occupy(
            dom,
            CompId(who),
            tick,
            pkt(IO_BASE, id, who),
            stats
        ));
    }

    #[test]
    fn staging_is_invisible_until_the_border() {
        let stats = PdesStats::default();
        let x = xbar2b();
        stage(&x, 1, 1, 10, 0xa, &stats);
        assert_eq!(x.staged_len(), 1);
        assert_eq!(stats.xbar_staged.load(Relaxed), 1);
        // No layer state was touched mid-window: a host-mode probe still
        // sees the layer free.
        assert!(matches!(
            x.try_occupy(IO_BASE, CompId(9)),
            Occupy::Granted { .. }
        ));
        assert_eq!(x.release(IO_BASE, CompId(9)), None);
        let grants = x.border_grants(16, &stats);
        assert_eq!(grants.len(), 1);
        assert_eq!(x.staged_len(), 0);
        assert_eq!(grants[0].target, CompId(10));
        assert_eq!(grants[0].pkt.id, 0xa);
    }

    #[test]
    fn same_tick_grants_tie_break_on_sender_domain_then_seq() {
        // Maximally skewed host append order: domain 2's whole window is
        // staged before domain 1's, and domain 2's own requests arrive
        // out of tick order. The grant order must come out canonical —
        // the reordered-grant regression mirroring
        // tests/inbox_order.rs::skewed_host_order_shows_nonzero_reordered_counter.
        let stats = PdesStats::default();
        let x = xbar2b();
        stage(&x, 2, 2, 30, 0xa, &stats);
        stage(&x, 2, 2, 10, 0xb, &stats);
        stage(&x, 1, 1, 10, 0xc, &stats);
        stage(&x, 1, 1, 30, 0xd, &stats);
        // One layer serves one transaction at a time: drive four borders
        // with a release in each window and record the grant order.
        let mut order = Vec::new();
        let mut border = 40;
        for _ in 0..4 {
            let grants = x.border_grants(border, &stats);
            assert_eq!(grants.len(), 1, "single layer grants one per border");
            order.push(grants[0].pkt.id);
            assert_eq!(
                grants[0].deliver, border,
                "in-window requests deliver at the border"
            );
            x.release(IO_BASE, CompId(grants[0].pkt.requester.0));
            border += 16;
        }
        assert_eq!(
            order,
            vec![0xc, 0xb, 0xd, 0xa],
            "(10,d1) < (10,d2) < (30,d1) < (30,d2)"
        );
        assert_eq!(x.border_grants(border, &stats).len(), 0, "drained");
    }

    #[test]
    fn occupied_layer_defers_to_a_later_border() {
        let stats = PdesStats::default();
        let x = xbar2b();
        stage(&x, 1, 1, 5, 1, &stats);
        let g = x.border_grants(16, &stats);
        assert_eq!(g.len(), 1);
        assert_eq!(stats.xbar_deferred_grants.load(Relaxed), 0);
        // The layer is occupied for the whole next window: a request
        // staged meanwhile is deferred, not granted.
        stage(&x, 2, 2, 20, 2, &stats);
        assert!(x.border_grants(32, &stats).is_empty());
        assert_eq!(x.pending_len(0), 1);
        assert_eq!(stats.xbar_deferred_grants.load(Relaxed), 1);
        // Release mid-window; the *next* border grants — never mid-window
        // (the occupancy snapshot the grant reads is the border's).
        x.release(IO_BASE, CompId(1));
        let g = x.border_grants(48, &stats);
        assert_eq!(g.len(), 1);
        assert_eq!(g[0].pkt.id, 2);
        assert_eq!(
            g[0].deliver, 48,
            "busy retry replays as a border-postponed delivery"
        );
        assert_eq!(x.pending_len(0), 0);
    }

    #[test]
    fn grant_postponement_is_accounted_like_the_inbox_merge() {
        let stats = PdesStats::default();
        let x = xbar2b();
        // Arrival (req_tick + latency = 10 + 5) before the border 32:
        // postponed, t_pp = 17.
        stage(&x, 1, 1, 10, 1, &stats);
        let g = x.border_grants(32, &stats);
        assert_eq!(g[0].deliver, 32);
        assert_eq!(stats.postponed.load(Relaxed), 1);
        assert_eq!(stats.tpp_sum.load(Relaxed), 17);
        x.release(IO_BASE, CompId(1));
        // Arrival exactly on the border: no postponement counted.
        stage(&x, 1, 1, 43, 2, &stats);
        let g = x.border_grants(48, &stats);
        assert_eq!(g[0].deliver, 48);
        assert_eq!(stats.postponed.load(Relaxed), 1, "48 == arrival: exact");
    }

    #[test]
    fn disjoint_layers_grant_independently_at_one_border() {
        let stats = PdesStats::default();
        let x = xbar2b();
        assert!(x.stage_occupy(
            1,
            CompId(1),
            10,
            pkt(IO_BASE, 1, 1),
            &stats
        ));
        assert!(x.stage_occupy(
            2,
            CompId(2),
            10,
            pkt(IO_BASE + IO_PAGE, 2, 2),
            &stats
        ));
        let g = x.border_grants(16, &stats);
        assert_eq!(g.len(), 2, "independent layers both grant");
        let targets: Vec<CompId> = g.iter().map(|g| g.target).collect();
        assert!(targets.contains(&CompId(10)) && targets.contains(&CompId(11)));
    }

    #[test]
    fn border_work_tracks_staged_and_pending() {
        let stats = PdesStats::default();
        let x = xbar2b();
        assert!(!x.has_border_work(), "fresh crossbar: IO-free border");
        stage(&x, 1, 1, 10, 1, &stats);
        assert!(x.has_border_work());
        // Grant consumes the staged request: back to IO-free.
        assert_eq!(x.border_grants(16, &stats).len(), 1);
        assert!(!x.has_border_work());
        // Deferred grants keep the border busy until they drain.
        stage(&x, 2, 2, 20, 2, &stats);
        assert!(x.border_grants(32, &stats).is_empty(), "layer occupied");
        assert!(x.has_border_work(), "pending carry-over is border work");
        x.release(IO_BASE, CompId(1));
        assert_eq!(x.border_grants(48, &stats).len(), 1);
        assert!(!x.has_border_work());
    }

    #[test]
    fn stage_rejects_unmapped_addresses() {
        let stats = PdesStats::default();
        let x = xbar2b();
        assert!(!x.stage_occupy(1, CompId(1), 0, pkt(0x1234, 1, 1), &stats));
        assert_eq!(x.staged_len(), 0);
        assert_eq!(stats.xbar_staged.load(Relaxed), 0);
    }

    #[test]
    fn program_order_within_one_domain_is_preserved() {
        let stats = PdesStats::default();
        let x = xbar2b();
        for id in 0..4u64 {
            stage(&x, 3, 3, 20, id, &stats);
        }
        let mut order = Vec::new();
        let mut border = 32;
        for _ in 0..4 {
            let g = x.border_grants(border, &stats);
            order.push(g[0].pkt.id);
            x.release(IO_BASE, CompId(3));
            border += 16;
        }
        assert_eq!(order, vec![0, 1, 2, 3], "seq preserves program order");
    }
}
