//! The non-coherent IO crossbar with thread-safe layers (paper §4.3).
//!
//! An N-to-M crossbar: each *layer* is a channel to one target that only one
//! initiator may hold at a time. Initiators occupy the layer, talk to the
//! target with the classic timing protocol, and release it when the response
//! returns; rejected initiators are woken with a retry.
//!
//! parti adaptation: the layer state sits behind a mutex. `try_occupy` uses
//! `try_lock` — initiators racing on *host* time (their local simulated
//! times may differ!) are simply rejected and retry, which the paper shows
//! is a special case of the existing occupy/retry protocol.
//!
//! gem5's IO-XBAR is a SimObject; here the crossbar is the shared layer
//! state plus direct event scheduling into the target's domain (semantics
//! identical; the crossing latency is charged on the scheduled delivery).

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::{Arc, Mutex};

use crate::sim::ids::CompId;
use crate::sim::stats::StatSink;
use crate::sim::time::{Tick, NS};

/// One layer: the channel to a single target.
#[derive(Default)]
struct Layer {
    occupied_by: Option<CompId>,
    waiting: Vec<CompId>,
}

/// Address range → target mapping entry.
#[derive(Clone, Copy, Debug)]
pub struct XbarTarget {
    pub base: u64,
    pub size: u64,
    pub comp: CompId,
}

pub struct XbarState {
    targets: Vec<XbarTarget>,
    layers: Vec<Mutex<Layer>>,
    /// Crossbar traversal latency (request and response each).
    pub latency: Tick,
    /// Retry backoff after a host-time mutex collision.
    pub retry_delay: Tick,
    // stats
    pub occupancies: AtomicU64,
    pub busy_rejects: AtomicU64,
    pub lock_rejects: AtomicU64,
}

/// Outcome of an occupancy attempt.
#[derive(Debug, PartialEq, Eq)]
pub enum Occupy {
    /// Layer acquired; deliver the request to `target`.
    Granted { target: CompId },
    /// Layer held by another initiator; a retry event will come.
    Busy,
    /// Host-time mutex collision (§4.3); retry after `retry_delay`.
    Contended,
    /// Address matches no target.
    NoTarget,
}

impl XbarState {
    pub fn new(targets: Vec<XbarTarget>, latency: Tick, retry_delay: Tick) -> Arc<Self> {
        let layers = (0..targets.len()).map(|_| Mutex::new(Layer::default())).collect();
        Arc::new(XbarState {
            targets,
            layers,
            latency,
            retry_delay,
            occupancies: AtomicU64::new(0),
            busy_rejects: AtomicU64::new(0),
            lock_rejects: AtomicU64::new(0),
        })
    }

    /// Index of the layer serving `addr`.
    pub fn layer_of(&self, addr: u64) -> Option<usize> {
        self.targets
            .iter()
            .position(|t| addr >= t.base && addr < t.base + t.size)
    }

    /// Try to occupy the layer for `addr` on behalf of `who`.
    pub fn try_occupy(&self, addr: u64, who: CompId) -> Occupy {
        let Some(idx) = self.layer_of(addr) else {
            return Occupy::NoTarget;
        };
        match self.layers[idx].try_lock() {
            Err(_) => {
                // Another domain thread holds the layer mutex *right now*:
                // treat as a transient rejection (paper §4.3).
                self.lock_rejects.fetch_add(1, Relaxed);
                Occupy::Contended
            }
            Ok(mut layer) => {
                if layer.occupied_by.is_some() {
                    self.busy_rejects.fetch_add(1, Relaxed);
                    if !layer.waiting.contains(&who) {
                        layer.waiting.push(who);
                    }
                    Occupy::Busy
                } else {
                    layer.occupied_by = Some(who);
                    self.occupancies.fetch_add(1, Relaxed);
                    Occupy::Granted { target: self.targets[idx].comp }
                }
            }
        }
    }

    /// Release the layer for `addr`; returns the next waiting initiator (to
    /// be sent a retry event), if any.
    pub fn release(&self, addr: u64, who: CompId) -> Option<CompId> {
        let idx = self.layer_of(addr)?;
        let mut layer = self.layers[idx].lock().unwrap();
        debug_assert_eq!(layer.occupied_by, Some(who), "release by non-holder");
        layer.occupied_by = None;
        if layer.waiting.is_empty() {
            None
        } else {
            Some(layer.waiting.remove(0))
        }
    }

    pub fn stats(&self, out: &mut StatSink) {
        out.add_u64("occupancies", self.occupancies.load(Relaxed));
        out.add_u64("busy_rejects", self.busy_rejects.load(Relaxed));
        out.add_u64("lock_rejects", self.lock_rejects.load(Relaxed));
    }
}

/// Default IO region layout: IO space starts at 256 GiB, each device gets a
/// 4 KiB page.
pub const IO_BASE: u64 = 0x40_0000_0000;
pub const IO_PAGE: u64 = 0x1000;

pub fn default_xbar(device_comps: &[CompId]) -> Arc<XbarState> {
    let targets = device_comps
        .iter()
        .enumerate()
        .map(|(i, &comp)| XbarTarget {
            base: IO_BASE + i as u64 * IO_PAGE,
            size: IO_PAGE,
            comp,
        })
        .collect();
    XbarState::new(targets, 5 * NS, NS)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn xbar2() -> Arc<XbarState> {
        default_xbar(&[CompId(10), CompId(11)])
    }

    #[test]
    fn grant_then_busy_then_release_retry() {
        let x = xbar2();
        let a = CompId(1);
        let b = CompId(2);
        assert_eq!(
            x.try_occupy(IO_BASE, a),
            Occupy::Granted { target: CompId(10) }
        );
        assert_eq!(x.try_occupy(IO_BASE, b), Occupy::Busy);
        assert_eq!(x.release(IO_BASE, a), Some(b));
        // b was popped from the wait list; now b can occupy
        assert_eq!(
            x.try_occupy(IO_BASE, b),
            Occupy::Granted { target: CompId(10) }
        );
        assert_eq!(x.release(IO_BASE, b), None);
    }

    #[test]
    fn disjoint_layers_are_independent() {
        let x = xbar2();
        assert!(matches!(
            x.try_occupy(IO_BASE, CompId(1)),
            Occupy::Granted { .. }
        ));
        assert!(matches!(
            x.try_occupy(IO_BASE + IO_PAGE, CompId(2)),
            Occupy::Granted { target } if target == CompId(11)
        ));
    }

    #[test]
    fn unmapped_address() {
        let x = xbar2();
        assert_eq!(x.try_occupy(0x1234, CompId(1)), Occupy::NoTarget);
    }

    #[test]
    fn no_duplicate_waiters() {
        let x = xbar2();
        x.try_occupy(IO_BASE, CompId(1));
        x.try_occupy(IO_BASE, CompId(2));
        x.try_occupy(IO_BASE, CompId(2));
        assert_eq!(x.release(IO_BASE, CompId(1)), Some(CompId(2)));
        assert_eq!(x.try_occupy(IO_BASE, CompId(2)), Occupy::Granted { target: CompId(10) });
        assert_eq!(x.release(IO_BASE, CompId(2)), None, "no stale waiter entry");
    }
}
