//! The border arbiter component of the IO crossbar (docs/XBAR.md).
//!
//! Under `--xbar-arb border` the crossbar's layer grants are a *border*
//! decision, not a mid-window race — but grants must become `MemReq`
//! events in the targets' domain, and the quiescent span of the border
//! protocol forbids cross-domain scheduling (each domain's mailbox may
//! already have been drained). [`XbarArbiter`] resolves this the same way
//! the inbox merge does: it is an ordinary [`Component`] elaborated into
//! the *shared* domain — the domain that owns every crossbar target — so
//! its [`Component::border_merge`] hook runs inside the quiescent span and
//! every granted delivery is a plain local schedule.
//!
//! The arbiter receives no events; it exists for its border hook and for
//! surfacing the crossbar's counters as per-component statistics.

use std::sync::Arc;

use crate::ckpt::io::{CkptError, StateReader, StateWriter};
use crate::sim::component::{Component, Ctx};
use crate::sim::event::{prio, EventKind};
use crate::sim::stats::StatSink;

use super::XbarState;

/// Shared-domain component running the crossbar's border-staged grant
/// protocol (one arbitration per quantum border, inside the quiescent
/// span) and reporting the crossbar statistics.
pub struct XbarArbiter {
    name: String,
    xbar: Arc<XbarState>,
    /// Grants issued by this arbiter's border passes (deterministic under
    /// `--xbar-arb border`).
    granted: u64,
    /// IO-free borders where the arbitration pass (and its lock) was
    /// skipped entirely — on most workloads the overwhelming majority.
    skipped_borders: u64,
}

impl XbarArbiter {
    pub fn new(name: String, xbar: Arc<XbarState>) -> Self {
        XbarArbiter { name, xbar, granted: 0, skipped_borders: 0 }
    }
}

impl Component for XbarArbiter {
    fn handle(&mut self, kind: EventKind, _ctx: &mut Ctx) {
        panic!("{}: unexpected event {kind:?}", self.name);
    }

    fn name(&self) -> &str {
        &self.name
    }

    /// Border-staged layer arbitration (`--xbar-arb border`): grant the
    /// closed window's staged layer requests in canonical
    /// `(request_tick, sender_domain, seq)` order and schedule each
    /// granted `MemReq` locally at `max(request_tick + latency, border)`.
    /// Runs before the shared domain publishes its post-sync `next_tick`,
    /// so granted deliveries count towards the horizon and staged traffic
    /// can never be dropped by a quiescent verdict.
    fn border_merge(&mut self, ctx: &mut Ctx) {
        if !ctx.xbar_border() {
            return;
        }
        // IO-free border fast path: nothing staged this window and no
        // carried-over pending grants — the arbitration would be a no-op,
        // so skip it (and the `arb` lock) on one relaxed load. Exact
        // because every sender is parked at the freeze barrier.
        if !self.xbar.has_border_work() {
            self.skipped_borders += 1;
            return;
        }
        let grants =
            self.xbar.border_grants(ctx.now(), &ctx.shared().pdes);
        self.granted += grants.len() as u64;
        for g in grants {
            ctx.schedule_abs_prio(
                g.deliver,
                g.target,
                EventKind::MemReq { pkt: g.pkt },
                prio::DEFAULT,
            );
        }
    }

    fn stats(&self, out: &mut StatSink) {
        out.add_u64("granted", self.granted);
        out.add_u64("skipped_borders", self.skipped_borders);
        let pending: u64 = (0..self.xbar.n_layers())
            .map(|l| self.xbar.pending_len(l) as u64)
            .sum();
        out.add_u64("pending", pending);
        self.xbar.stats(out);
    }

    /// The arbiter owns the crossbar's serialized image: it is the one
    /// component holding the `XbarState` in elaboration order (sequencers
    /// share the `Arc` but never serialize it).
    fn save_state(&self, w: &mut StateWriter) {
        self.xbar.save_ckpt(w);
        w.u64(self.granted);
        w.u64(self.skipped_borders);
    }

    fn restore_state(&mut self, r: &mut StateReader) -> Result<(), CkptError> {
        self.xbar.restore_ckpt(r)?;
        self.granted = r.u64()?;
        self.skipped_borders = r.u64()?;
        Ok(())
    }
}
