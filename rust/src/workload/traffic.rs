//! Traffic elaboration: turn a [`TrafficSpec`] into per-core op traces.
//!
//! This is the generator half of the declarative traffic engine (the
//! spec half — schema, validation, TOML, the scenario registry — lives
//! in [`crate::spec::traffic`]). Each core's stream comes from its own
//! counter-based RNG stream keyed by `(seed, core)`: op `k` of core `c`
//! reads counters `base_ctr(c) + 4k .. + 4k+3`, exactly the
//! [`super::gen::addrgen`] discipline, salted so traffic streams never
//! alias the app generator's. Elaboration is therefore a pure function
//! of `(spec, n_cores, ops_per_core)` — independent of thread count,
//! steal decisions and host timing — which is what lets
//! `tests/traffic.rs` assert threaded ≡ virtual bit-identity for every
//! pattern (docs/TRAFFIC.md carries the determinism argument).

use std::sync::Arc;

use crate::spec::traffic::{TrafficPattern, TrafficSpec};

use super::apps::{PRIVATE_BASE, PRIVATE_SPAN, SHARED_BASE};
use super::gen::{squares32, GenOp, SQUARES_KEY};
use super::trace::{CoreTrace, Workload};

/// XORed into every traffic counter stream so that a traffic run with
/// seed `s` never replays the byte-identical RNG draws of an app trace
/// with the same seed.
pub const TRAFFIC_SALT: u64 = 0xB5AD_4ECE_DA1C_E2A9;

/// Traffic addresses are 64-byte-line aligned, like every generator.
const LINE_BYTES: u64 = 64;

/// Base of core `c`'s private region (the [`super::apps`] memory map;
/// "private" is a convention — any core may address any region, which
/// is exactly what the remote patterns do).
fn private_base(core: usize) -> u64 {
    PRIVATE_BASE + core as u64 * PRIVATE_SPAN
}

/// The transpose partner of `core` among `n` cores: on a perfect
/// square `n = s*s`, core `(r, c)` maps to `(c, r)`; otherwise the
/// antidiagonal partner `n-1-core` (still a fixed-point-free-ish
/// involution, still long paths on a mesh).
pub fn transpose_partner(core: usize, n: usize) -> usize {
    let s = (1..=n).find(|&s| s * s >= n).unwrap_or(1);
    if s * s == n {
        (core % s) * s + core / s
    } else {
        n - 1 - core
    }
}

/// X-then-Y hop distance between two cores' stations on a `cols`-wide
/// mesh — the metric behind the transpose-vs-neighbor shape gate.
pub fn mesh_hops(cols: usize, a: usize, b: usize) -> usize {
    let cols = cols.max(1);
    (a % cols).abs_diff(b % cols) + (a / cols).abs_diff(b / cols)
}

/// Generate core `core`'s op stream for one scenario. Pure function of
/// its arguments; see the module docs for the counter discipline.
pub fn ops_for_core(
    spec: &TrafficSpec,
    core: usize,
    n_cores: usize,
    ops_per_core: usize,
) -> Vec<GenOp> {
    let n = n_cores.max(1);
    let working_lines = spec.working_lines.max(1);
    let shared_lines = spec.shared_lines.max(1);
    let phase_ops = spec.phase_ops.max(1);
    let base_ctr = spec.seed ^ ((core as u64) << 40) ^ TRAFFIC_SALT;

    (0..ops_per_core as u64)
        .map(|k| {
            let ctr = base_ctr.wrapping_add(k.wrapping_mul(4));
            let r0 = squares32(ctr, SQUARES_KEY);
            let r1 = squares32(ctr.wrapping_add(1), SQUARES_KEY);
            let r2 = squares32(ctr.wrapping_add(2), SQUARES_KEY);
            let r3 = squares32(ctr.wrapping_add(3), SQUARES_KEY);

            // Odd phases of bursty-phase run at burst intensity; every
            // other pattern holds the base intensity throughout.
            let intensity = match spec.pattern {
                TrafficPattern::BurstyPhase
                    if (k as usize / phase_ops) % 2 == 1 =>
                {
                    spec.burst_intensity_milli
                }
                _ => spec.intensity_milli,
            };

            let line = (r1 as u64) % working_lines;
            let remote = ((r0 % 1000) as u64) < spec.sharing_milli;
            let mut is_store = ((r2 % 1000) as u64) < spec.store_milli;
            let addr = if !remote {
                private_base(core) + line * LINE_BYTES
            } else {
                match spec.pattern {
                    TrafficPattern::UniformRandom
                    | TrafficPattern::BurstyPhase => {
                        private_base(r3 as usize % n) + line * LINE_BYTES
                    }
                    TrafficPattern::Hotspot => {
                        SHARED_BASE + ((r1 as u64) % shared_lines) * LINE_BYTES
                    }
                    TrafficPattern::Transpose => {
                        private_base(transpose_partner(core, n))
                            + line * LINE_BYTES
                    }
                    TrafficPattern::Neighbor => {
                        private_base((core + 1) % n) + line * LINE_BYTES
                    }
                    TrafficPattern::ProducerConsumer => {
                        // The even core of each pair produces (stores),
                        // the odd core consumes (loads).
                        is_store = core % 2 == 0;
                        let pair = (core / 2) as u64;
                        SHARED_BASE
                            + (pair * shared_lines + (r1 as u64) % shared_lines)
                                * LINE_BYTES
                    }
                }
            };
            GenOp {
                addr,
                is_store,
                gap: ((1000 - intensity.min(1000)) / 100) as u32,
            }
        })
        .collect()
}

/// Elaborate a whole workload from a scenario: one trace per core, no
/// software barriers (intensity shapes the load instead), and the
/// phase structure recorded for the stats layer
/// ([`Workload::phases`] / the `traffic_phases` counter).
pub fn traffic_workload(
    spec: &TrafficSpec,
    n_cores: usize,
    ops_per_core: usize,
) -> Workload {
    let cores = (0..n_cores)
        .map(|c| {
            Arc::new(CoreTrace::from_ops(
                c as u16,
                &ops_for_core(spec, c, n_cores, ops_per_core),
            ))
        })
        .collect();
    Workload {
        cores,
        barrier_every: 0,
        name: spec.name.clone(),
        phase_ops: if spec.pattern == TrafficPattern::BurstyPhase {
            spec.phase_ops
        } else {
            0
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::traffic::{scenario, scenarios, MAX_WORKING_LINES};

    fn spec_for(pattern: TrafficPattern) -> TrafficSpec {
        scenarios()
            .into_iter()
            .find(|s| s.pattern == pattern)
            .expect("one scenario per pattern")
    }

    #[test]
    fn elaboration_is_deterministic_and_seed_sensitive() {
        for t in scenarios() {
            let a = traffic_workload(&t, 4, 128);
            let b = traffic_workload(&t, 4, 128);
            for (ca, cb) in a.cores.iter().zip(&b.cores) {
                assert_eq!(ca.addr, cb.addr, "{}", t.name);
                assert_eq!(ca.is_store, cb.is_store, "{}", t.name);
                assert_eq!(ca.gap, cb.gap, "{}", t.name);
            }
            let other = TrafficSpec { seed: t.seed + 1, ..t.clone() };
            let c = traffic_workload(&other, 4, 128);
            assert_ne!(a.cores[0].addr, c.cores[0].addr, "{}", t.name);
        }
    }

    #[test]
    fn streams_are_independent_of_core_count_prefix() {
        // Core 1's stream must not depend on how many cores exist for
        // patterns whose targets don't encode the core count.
        let t = spec_for(TrafficPattern::Hotspot);
        let small = traffic_workload(&t, 2, 64);
        let big = traffic_workload(&t, 8, 64);
        assert_eq!(small.cores[1].addr, big.cores[1].addr);
    }

    #[test]
    fn salt_decorrelates_from_addrgen() {
        let p = super::super::gen::AddrGenParams::default();
        let app = super::super::gen::addrgen(&p, 64);
        let t = TrafficSpec { seed: p.seed, ..TrafficSpec::default() };
        let ops = ops_for_core(&t, 0, 4, 64);
        assert_ne!(
            app.iter().map(|o| o.addr).collect::<Vec<_>>(),
            ops.iter().map(|o| o.addr).collect::<Vec<_>>()
        );
    }

    #[test]
    fn all_addrs_line_aligned_and_in_range() {
        for t in scenarios() {
            let w = traffic_workload(&t, 8, 256);
            for c in &w.cores {
                for &a in &c.addr {
                    assert_eq!(a % LINE_BYTES, 0, "{}", t.name);
                    assert!(
                        a >= PRIVATE_BASE,
                        "{}: addr {a:#x} below the map",
                        t.name
                    );
                }
            }
        }
    }

    #[test]
    fn sharing_zero_stays_private() {
        for &p in crate::spec::traffic::ALL_PATTERNS {
            let t = TrafficSpec { sharing_milli: 0, ..spec_for(p) };
            let ops = ops_for_core(&t, 2, 8, 256);
            let base = private_base(2);
            assert!(
                ops.iter().all(|o| o.addr >= base
                    && o.addr < base + MAX_WORKING_LINES * LINE_BYTES),
                "{p:?} leaked out of the private region"
            );
        }
    }

    #[test]
    fn hotspot_remote_confined_to_window() {
        let t = spec_for(TrafficPattern::Hotspot);
        let hi = SHARED_BASE + t.shared_lines * LINE_BYTES;
        let ops = ops_for_core(&t, 0, 8, 2048);
        let remote: Vec<_> =
            ops.iter().filter(|o| o.addr >= SHARED_BASE).collect();
        assert!(!remote.is_empty(), "sharing 700 must go remote");
        assert!(remote.iter().all(|o| o.addr < hi), "window overflow");
    }

    #[test]
    fn transpose_partner_is_an_involution() {
        for n in [4usize, 9, 16, 64, 7, 12] {
            for c in 0..n {
                let p = transpose_partner(c, n);
                assert!(p < n);
                assert_eq!(transpose_partner(p, n), c, "n={n} c={c}");
            }
        }
    }

    #[test]
    fn transpose_crosses_more_mesh_hops_than_neighbor() {
        // The ISSUE's shape gate at the pattern level: on an 8x8 mesh,
        // the transpose exchange covers strictly more station hops
        // than the halo exchange (the sim-level gate in
        // tests/traffic.rs builds on this geometry).
        let (n, cols) = (64usize, 8usize);
        let tr: usize = (0..n)
            .map(|c| mesh_hops(cols, c, transpose_partner(c, n)))
            .sum();
        let nb: usize = (0..n).map(|c| mesh_hops(cols, c, (c + 1) % n)).sum();
        assert!(tr > 2 * nb, "transpose {tr} vs neighbor {nb}");
    }

    #[test]
    fn producer_consumer_roles_and_disjoint_buffers() {
        let t = spec_for(TrafficPattern::ProducerConsumer);
        let prod = ops_for_core(&t, 0, 8, 512);
        let cons = ops_for_core(&t, 1, 8, 512);
        let pair0_hi = SHARED_BASE + t.shared_lines * LINE_BYTES;
        for o in prod.iter().filter(|o| o.addr >= SHARED_BASE) {
            assert!(o.is_store, "producers store");
            assert!(o.addr < pair0_hi, "pair 0 stays in its buffer");
        }
        for o in cons.iter().filter(|o| o.addr >= SHARED_BASE) {
            assert!(!o.is_store, "consumers load");
            assert!(o.addr < pair0_hi, "pair 0 stays in its buffer");
        }
        let pair1 = ops_for_core(&t, 2, 8, 512);
        for o in pair1.iter().filter(|o| o.addr >= SHARED_BASE) {
            assert!(o.addr >= pair0_hi, "pair 1 buffer is disjoint");
        }
    }

    #[test]
    fn bursty_phases_alternate_gap() {
        let t = spec_for(TrafficPattern::BurstyPhase);
        let ops = ops_for_core(&t, 0, 4, 4 * t.phase_ops);
        let calm_gap = ((1000 - t.intensity_milli) / 100) as u32;
        let burst_gap = ((1000 - t.burst_intensity_milli) / 100) as u32;
        assert_ne!(calm_gap, burst_gap, "scenario must separate phases");
        for (i, o) in ops.iter().enumerate() {
            let expect = if (i / t.phase_ops) % 2 == 1 {
                burst_gap
            } else {
                calm_gap
            };
            assert_eq!(o.gap, expect, "op {i}");
        }
        let w = traffic_workload(&t, 4, 4 * t.phase_ops);
        assert_eq!(w.phases(), 4);
    }

    #[test]
    fn intensity_shapes_gap() {
        let lazy = TrafficSpec {
            intensity_milli: 100,
            ..scenario("uniform-random").unwrap()
        };
        let eager = TrafficSpec { intensity_milli: 1000, ..lazy.clone() };
        assert!(ops_for_core(&lazy, 0, 4, 64).iter().all(|o| o.gap == 9));
        assert!(ops_for_core(&eager, 0, 4, 64).iter().all(|o| o.gap == 0));
    }

    #[test]
    fn workload_carries_name_and_phase_structure() {
        let t = scenario("hotspot").unwrap();
        let w = traffic_workload(&t, 4, 128);
        assert_eq!(w.name, "hotspot");
        assert_eq!(w.n_cores(), 4);
        assert_eq!(w.total_ops(), 512);
        assert_eq!(w.phase_ops, 0, "only bursty-phase records phases");
        assert_eq!(w.phases(), 0);
    }
}
