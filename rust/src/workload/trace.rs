//! Trace containers: the op streams the simulated cores execute.

use std::sync::Arc;

use super::gen::{addrgen, store_value, AddrGenParams, GenOp};

/// The op stream for one core.
#[derive(Clone, Debug, Default)]
pub struct CoreTrace {
    pub addr: Vec<u64>,
    pub is_store: Vec<bool>,
    /// Compute-cycle gap before each op.
    pub gap: Vec<u32>,
    /// Functional store payloads (same length; ignored for loads).
    pub value: Vec<u64>,
    /// Optional expected load values (empty = unchecked; `u64::MAX` entry =
    /// skip). Lets coherence tests assert exact data visibility.
    pub expected: Vec<u64>,
}

/// Sentinel in [`CoreTrace::expected`]: don't check this op.
pub const NO_EXPECT: u64 = u64::MAX;

impl CoreTrace {
    pub fn len(&self) -> usize {
        self.addr.len()
    }

    pub fn is_empty(&self) -> bool {
        self.addr.is_empty()
    }

    pub fn from_ops(core: u16, ops: &[GenOp]) -> Self {
        CoreTrace {
            addr: ops.iter().map(|o| o.addr).collect(),
            is_store: ops.iter().map(|o| o.is_store).collect(),
            gap: ops.iter().map(|o| o.gap).collect(),
            value: ops
                .iter()
                .enumerate()
                .map(|(i, _)| store_value(core, i as u64))
                .collect(),
            expected: Vec::new(),
        }
    }

    /// Build from raw artifact outputs (`workload.hlo.txt` execution).
    pub fn from_arrays(
        core: u16,
        addr: Vec<u64>,
        is_store_u32: Vec<u32>,
        gap: Vec<u32>,
    ) -> Self {
        let n = addr.len();
        CoreTrace {
            addr,
            is_store: is_store_u32.iter().map(|&s| s != 0).collect(),
            gap,
            value: (0..n as u64).map(|i| store_value(core, i)).collect(),
            expected: Vec::new(),
        }
    }
}

/// The full workload: one trace per core plus synchronisation structure.
#[derive(Clone, Debug, Default)]
pub struct Workload {
    pub cores: Vec<Arc<CoreTrace>>,
    /// Software barrier every N ops (0 = none).
    pub barrier_every: usize,
    /// Human-readable name ("blackscholes", ...).
    pub name: String,
    /// Traffic phase length in ops (0 = unphased). Set by the
    /// `bursty-phase` traffic pattern ([`crate::workload::traffic`]);
    /// the stats layer reports the resulting phase count as
    /// `traffic_phases`.
    pub phase_ops: usize,
}

impl Workload {
    /// Procedural construction (the Rust fallback path; the artifact path
    /// in [`crate::runtime`] must produce bit-identical traces).
    pub fn generate(
        name: &str,
        params: &[AddrGenParams],
        ops_per_core: usize,
        barrier_every: usize,
    ) -> Self {
        let cores = params
            .iter()
            .map(|p| {
                Arc::new(CoreTrace::from_ops(
                    p.core_id as u16,
                    &addrgen(p, ops_per_core),
                ))
            })
            .collect();
        Workload { cores, barrier_every, name: name.to_string(), phase_ops: 0 }
    }

    pub fn n_cores(&self) -> usize {
        self.cores.len()
    }

    pub fn total_ops(&self) -> usize {
        self.cores.iter().map(|c| c.len()).sum()
    }

    /// Number of traffic phases the longest core trace spans (0 for
    /// unphased workloads) — surfaced as the `traffic_phases` counter.
    pub fn phases(&self) -> usize {
        if self.phase_ops == 0 {
            return 0;
        }
        self.cores
            .iter()
            .map(|c| c.len())
            .max()
            .unwrap_or(0)
            .div_ceil(self.phase_ops)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generate_shapes() {
        let params: Vec<AddrGenParams> = (0..4)
            .map(|i| AddrGenParams { core_id: i, ..Default::default() })
            .collect();
        let w = Workload::generate("t", &params, 256, 64);
        assert_eq!(w.n_cores(), 4);
        assert_eq!(w.total_ops(), 1024);
        assert_eq!(w.cores[0].len(), 256);
        assert_eq!(w.cores[0].value.len(), 256);
    }

    #[test]
    fn from_arrays_matches_from_ops() {
        let p = AddrGenParams::default();
        let ops = addrgen(&p, 128);
        let a = CoreTrace::from_ops(0, &ops);
        let b = CoreTrace::from_arrays(
            0,
            ops.iter().map(|o| o.addr).collect(),
            ops.iter().map(|o| o.is_store as u32).collect(),
            ops.iter().map(|o| o.gap).collect(),
        );
        assert_eq!(a.addr, b.addr);
        assert_eq!(a.is_store, b.is_store);
        assert_eq!(a.value, b.value);
    }
}
