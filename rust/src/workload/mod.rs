//! Workloads: op traces, the procedural generator (bit-exact port of the
//! Pallas kernel) and the application registry (Table 3).

pub mod apps;
pub mod gen;
pub mod trace;

pub use apps::{app_by_name, App, AppTraits, APPS, FIG8_APPS};
pub use gen::{addrgen, squares32, store_value, AddrGenParams, GenOp};
pub use trace::{CoreTrace, Workload};
