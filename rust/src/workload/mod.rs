//! Workloads: op traces, the procedural generator (bit-exact port of the
//! Pallas kernel), the application registry (Table 3) and the synthetic
//! traffic elaborator ([`crate::spec::traffic`] holds the spec side).

pub mod apps;
pub mod gen;
pub mod trace;
pub mod traffic;

pub use apps::{app_by_name, App, AppTraits, APPS, FIG8_APPS};
pub use gen::{addrgen, squares32, store_value, AddrGenParams, GenOp};
pub use trace::{CoreTrace, Workload};
pub use traffic::{traffic_workload, TRAFFIC_SALT};
