//! Workload registry: the paper's applications as trace parameterisations.
//!
//! Table 3 of the paper characterises the PARSEC subset by parallelisation
//! model, granularity, data sharing and data exchange; those axes (plus
//! STREAM's bandwidth-bound behaviour and the synthetic benchmark's
//! cache-resident behaviour) map onto the `addrgen` knobs below. The
//! *numeric payloads* (Black-Scholes prices, triad results) come from the
//! corresponding Pallas kernels via the AOT artifacts.

use super::gen::AddrGenParams;
use super::trace::Workload;

/// Table 3 characterisation (printed by `parti-sim tables --which 3`).
#[derive(Clone, Copy, Debug)]
pub struct AppTraits {
    pub name: &'static str,
    pub model: &'static str,
    pub granularity: &'static str,
    pub sharing: &'static str,
    pub exchange: &'static str,
}

/// A runnable application: traits + trace parameterisation.
#[derive(Clone, Copy, Debug)]
pub struct App {
    pub traits_: AppTraits,
    /// Fraction of accesses to the global shared region (milli).
    pub share_milli: u64,
    /// Fraction of private accesses that are random (milli).
    pub random_milli: u64,
    /// Store fraction (milli).
    pub store_milli: u64,
    /// Private working-set bytes per core.
    pub private_size: u64,
    /// Shared region bytes.
    pub shared_size: u64,
    pub stride: u64,
    /// Compute cycles between memory ops: base + U[0,spread).
    pub compute_base: u64,
    pub compute_spread: u64,
    /// Software barrier every N ops (0 = none).
    pub barrier_every: usize,
}

const KB: u64 = 1024;
const MB: u64 = 1024 * 1024;

/// Per-core private regions are spaced 64 MiB apart.
pub const PRIVATE_BASE: u64 = 0x1000_0000;
pub const PRIVATE_SPAN: u64 = 64 * MB;
pub const SHARED_BASE: u64 = 0x8000_0000;

pub const APPS: &[App] = &[
    // The custom bare-metal benchmark (§5.1): per-core sort, everything in
    // the private caches, no sharing, no barriers.
    App {
        traits_: AppTraits {
            name: "synthetic",
            model: "bare-metal",
            granularity: "coarse",
            sharing: "none",
            exchange: "none",
        },
        share_milli: 0,
        random_milli: 150,
        store_milli: 300,
        private_size: 16 * KB,
        shared_size: 4 * MB,
        stride: 1,
        compute_base: 3,
        compute_spread: 4,
        barrier_every: 0,
    },
    App {
        traits_: AppTraits {
            name: "blackscholes",
            model: "data-parallel",
            granularity: "coarse",
            sharing: "low",
            exchange: "low",
        },
        share_milli: 40,
        random_milli: 100,
        store_milli: 250,
        private_size: 24 * KB,
        shared_size: 8 * MB,
        stride: 1,
        compute_base: 8,
        compute_spread: 8,
        barrier_every: 4096,
    },
    App {
        traits_: AppTraits {
            name: "canneal",
            model: "unstructured",
            granularity: "fine",
            sharing: "high",
            exchange: "high",
        },
        share_milli: 400,
        random_milli: 800,
        store_milli: 300,
        private_size: 256 * KB,
        shared_size: 32 * MB,
        stride: 7,
        compute_base: 2,
        compute_spread: 3,
        barrier_every: 0,
    },
    App {
        traits_: AppTraits {
            name: "dedup",
            model: "pipeline",
            granularity: "medium",
            sharing: "high",
            exchange: "high",
        },
        share_milli: 350,
        random_milli: 400,
        store_milli: 400,
        private_size: 128 * KB,
        shared_size: 16 * MB,
        stride: 3,
        compute_base: 3,
        compute_spread: 4,
        barrier_every: 512,
    },
    App {
        traits_: AppTraits {
            name: "ferret",
            model: "pipeline",
            granularity: "medium",
            sharing: "high",
            exchange: "high",
        },
        share_milli: 300,
        random_milli: 500,
        store_milli: 300,
        private_size: 160 * KB,
        shared_size: 16 * MB,
        stride: 5,
        compute_base: 4,
        compute_spread: 6,
        barrier_every: 1024,
    },
    App {
        traits_: AppTraits {
            name: "fluidanimate",
            model: "data-parallel",
            granularity: "fine",
            sharing: "low",
            exchange: "medium",
        },
        share_milli: 120,
        random_milli: 300,
        store_milli: 350,
        private_size: 64 * KB,
        shared_size: 8 * MB,
        stride: 2,
        compute_base: 4,
        compute_spread: 4,
        barrier_every: 1024,
    },
    App {
        traits_: AppTraits {
            name: "swaptions",
            model: "data-parallel",
            granularity: "coarse",
            sharing: "low",
            exchange: "low",
        },
        share_milli: 25,
        random_milli: 150,
        store_milli: 250,
        private_size: 16 * KB,
        shared_size: 4 * MB,
        stride: 1,
        compute_base: 10,
        compute_spread: 10,
        barrier_every: 8192,
    },
    // STREAM: maximise DRAM traffic — huge per-core streaming working set,
    // zero reuse, triad-like 1-store-per-2-loads mix (§5.1).
    App {
        traits_: AppTraits {
            name: "stream",
            model: "data-parallel",
            granularity: "coarse",
            sharing: "low",
            exchange: "high",
        },
        share_milli: 0,
        random_milli: 0,
        store_milli: 333,
        private_size: 48 * MB,
        shared_size: 4 * MB,
        stride: 1,
        compute_base: 0,
        compute_spread: 1,
        barrier_every: 0,
    },
];

pub fn app_by_name(name: &str) -> Option<&'static App> {
    APPS.iter().find(|a| a.traits_.name == name)
}

/// Names of the PARSEC subset + STREAM evaluated at 32 cores (Fig. 8/9).
pub const FIG8_APPS: &[&str] = &[
    "blackscholes",
    "canneal",
    "dedup",
    "ferret",
    "fluidanimate",
    "swaptions",
    "stream",
];

impl App {
    /// `addrgen` parameter block for one core.
    pub fn params_for_core(&self, core: u64, seed: u64) -> AddrGenParams {
        AddrGenParams {
            seed,
            core_id: core,
            offset: 0,
            private_base: PRIVATE_BASE + core * PRIVATE_SPAN,
            private_size: self.private_size,
            shared_base: SHARED_BASE,
            shared_size: self.shared_size,
            stride: self.stride,
            share_milli: self.share_milli,
            random_milli: self.random_milli,
            line_bytes: 64,
            compute_base: self.compute_base,
            compute_spread: self.compute_spread,
            store_milli: self.store_milli,
        }
    }

    /// Procedurally generate the workload (fallback path; see
    /// [`crate::runtime::artifact_workload`] for the artifact path).
    pub fn generate(&self, n_cores: usize, ops_per_core: usize, seed: u64) -> Workload {
        let params: Vec<AddrGenParams> = (0..n_cores as u64)
            .map(|c| self.params_for_core(c, seed))
            .collect();
        Workload::generate(
            self.traits_.name,
            &params,
            ops_per_core,
            self.barrier_every,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_contains_paper_apps() {
        for name in FIG8_APPS {
            assert!(app_by_name(name).is_some(), "{name} missing");
        }
        assert!(app_by_name("synthetic").is_some());
    }

    #[test]
    fn private_regions_disjoint() {
        let app = app_by_name("stream").unwrap();
        let a = app.params_for_core(0, 1);
        let b = app.params_for_core(1, 1);
        assert!(a.private_base + a.private_size <= b.private_base);
    }

    #[test]
    fn high_sharing_apps_share_more() {
        let canneal = app_by_name("canneal").unwrap();
        let swaptions = app_by_name("swaptions").unwrap();
        assert!(canneal.share_milli > 5 * swaptions.share_milli);
    }

    #[test]
    fn synthetic_fits_l1() {
        let s = app_by_name("synthetic").unwrap();
        assert!(s.private_size <= 64 * KB, "must fit the L1D (Table 2)");
        assert_eq!(s.share_milli, 0);
    }
}
