//! Procedural trace generator — a bit-exact Rust port of the Pallas
//! `addrgen` kernel (python/compile/kernels/addrgen.py).
//!
//! The canonical trace source is the AOT artifact executed via
//! [`crate::runtime`]; this port exists so that (a) the simulator can run
//! without artifacts (CI, unit tests), and (b) the artifact path can be
//! *verified* against an independent implementation
//! (rust/tests/artifact_parity.rs). Keep all three implementations in sync:
//! addrgen.py, ref.py, and this file.

/// squares32 key (Widynski) — must match `ref.SQUARES_KEY`.
pub const SQUARES_KEY: u64 = 0xC58EFD154CE32F6D;

/// 32-bit counter-based RNG (squares32).
#[inline]
pub fn squares32(ctr: u64, key: u64) -> u32 {
    let mut x = ctr.wrapping_mul(key);
    let y = x;
    let z = y.wrapping_add(key);
    x = x.wrapping_mul(x).wrapping_add(y);
    x = (x >> 32) | (x << 32);
    x = x.wrapping_mul(x).wrapping_add(z);
    x = (x >> 32) | (x << 32);
    x = x.wrapping_mul(x).wrapping_add(y);
    x = (x >> 32) | (x << 32);
    x = x.wrapping_mul(x).wrapping_add(z);
    (x >> 32) as u32
}

/// Parameter block — layout mirrors addrgen.py's `params` vector.
#[derive(Clone, Copy, Debug)]
pub struct AddrGenParams {
    pub seed: u64,
    pub core_id: u64,
    pub offset: u64,
    pub private_base: u64,
    pub private_size: u64,
    pub shared_base: u64,
    pub shared_size: u64,
    pub stride: u64,
    pub share_milli: u64,
    pub random_milli: u64,
    pub line_bytes: u64,
    pub compute_base: u64,
    pub compute_spread: u64,
    pub store_milli: u64,
}

impl Default for AddrGenParams {
    fn default() -> Self {
        AddrGenParams {
            seed: 42,
            core_id: 0,
            offset: 0,
            private_base: 0x1000_0000,
            private_size: 64 * 1024,
            shared_base: 0x8000_0000,
            shared_size: 8 * 1024 * 1024,
            stride: 1,
            share_milli: 100,
            random_milli: 200,
            line_bytes: 64,
            compute_base: 2,
            compute_spread: 8,
            store_milli: 300,
        }
    }
}

impl AddrGenParams {
    /// Serialise to the uint64[16] vector the AOT artifact expects.
    pub fn to_vec(&self) -> Vec<u64> {
        let mut v = vec![0u64; 16];
        v[0] = self.seed;
        v[1] = self.core_id;
        v[2] = self.offset;
        v[3] = self.private_base;
        v[4] = self.private_size;
        v[5] = self.shared_base;
        v[6] = self.shared_size;
        v[7] = self.stride;
        v[8] = self.share_milli;
        v[9] = self.random_milli;
        v[10] = self.line_bytes;
        v[11] = self.compute_base;
        v[12] = self.compute_spread;
        v[13] = self.store_milli;
        v
    }
}

/// One generated trace element.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GenOp {
    pub addr: u64,
    pub is_store: bool,
    /// Compute-cycle gap before this op.
    pub gap: u32,
}

/// Generate `n` ops (mirror of the Pallas kernel body).
pub fn addrgen(p: &AddrGenParams, n: usize) -> Vec<GenOp> {
    let key = SQUARES_KEY;
    let line_bytes = p.line_bytes.max(1);
    let private_lines = (p.private_size / line_bytes).max(1);
    let shared_lines = (p.shared_size / line_bytes).max(1);
    let base_ctr = p.seed ^ (p.core_id << 40);
    let spread = (p.compute_spread as u32).max(1);

    (0..n as u64)
        .map(|k| {
            let i = p.offset.wrapping_add(k);
            let ctr = base_ctr.wrapping_add(i.wrapping_mul(4));
            let r0 = squares32(ctr, key);
            let r1 = squares32(ctr.wrapping_add(1), key);
            let r2 = squares32(ctr.wrapping_add(2), key);
            let r3 = squares32(ctr.wrapping_add(3), key);

            // One line per 8 sequential ops (spatial locality within a
            // 64B line) — mirror of addrgen.py.
            let seq_line = (i >> 3).wrapping_mul(p.stride) % private_lines;
            let rnd_line = (r1 as u64) % private_lines;
            let use_rnd = (r1 % 1000) < p.random_milli as u32;
            let priv_line = if use_rnd { rnd_line } else { seq_line };
            let priv_addr = p.private_base + priv_line * line_bytes;

            let shared_line = (r1 as u64) % shared_lines;
            let shared_addr = p.shared_base + shared_line * line_bytes;

            let use_shared = (r0 % 1000) < p.share_milli as u32;
            GenOp {
                addr: if use_shared { shared_addr } else { priv_addr },
                is_store: (r2 % 1000) < p.store_milli as u32,
                gap: p.compute_base as u32 + r3 % spread,
            }
        })
        .collect()
}

/// Deterministic functional store value for core/op-index (independent of
/// the trace source, shared by tests and the CPU models).
#[inline]
pub fn store_value(core: u16, idx: u64) -> u64 {
    let ctr = (core as u64) << 48 | idx;
    ((squares32(ctr, SQUARES_KEY) as u64) << 32)
        | squares32(ctr.wrapping_add(1), SQUARES_KEY) as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let p = AddrGenParams::default();
        assert_eq!(addrgen(&p, 64), addrgen(&p, 64));
    }

    #[test]
    fn offset_continuation() {
        let p = AddrGenParams::default();
        let full = addrgen(&p, 128);
        let a = addrgen(&p, 64);
        let b = addrgen(&AddrGenParams { offset: 64, ..p }, 64);
        assert_eq!(&full[..64], &a[..]);
        assert_eq!(&full[64..], &b[..]);
    }

    #[test]
    fn share_milli_bounds_regions() {
        let p = AddrGenParams { share_milli: 0, ..Default::default() };
        assert!(addrgen(&p, 512)
            .iter()
            .all(|o| o.addr >= p.private_base
                && o.addr < p.private_base + p.private_size));
        let p = AddrGenParams { share_milli: 1000, ..Default::default() };
        assert!(addrgen(&p, 512).iter().all(|o| o.addr >= p.shared_base));
    }

    #[test]
    fn line_aligned() {
        let p = AddrGenParams::default();
        assert!(addrgen(&p, 256).iter().all(|o| o.addr % 64 == 0));
    }

    #[test]
    fn gap_bounds() {
        let p = AddrGenParams {
            compute_base: 5,
            compute_spread: 10,
            ..Default::default()
        };
        assert!(addrgen(&p, 256).iter().all(|o| o.gap >= 5 && o.gap < 15));
    }

    #[test]
    fn cores_differ() {
        let a = addrgen(&AddrGenParams::default(), 64);
        let b = addrgen(
            &AddrGenParams { core_id: 1, ..Default::default() },
            64,
        );
        assert_ne!(a, b);
    }

    #[test]
    fn store_fraction_rough() {
        let p = AddrGenParams { store_milli: 300, ..Default::default() };
        let ops = addrgen(&p, 8192);
        let frac =
            ops.iter().filter(|o| o.is_store).count() as f64 / 8192.0;
        assert!(frac > 0.25 && frac < 0.35, "store fraction {frac}");
    }
}
