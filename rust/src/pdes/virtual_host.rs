//! The virtual-parallel kernel: sequentialized PDES + host model.
//!
//! **Why this exists** (DESIGN.md §3): the paper's speedups were measured on
//! a 64-core host; this machine has one core, so wall-clock speedup of the
//! threaded kernel is meaningless here. This kernel executes the *identical*
//! PDES semantics — same windows, same postpone-to-border rule, same barrier
//! protocol — on one thread, round-robin over domains, which makes the
//! timing-deviation results (the accuracy half of every figure) exact and
//! deterministic. Under the border-ordered inbox handoff
//! (`--inbox-order border`, the default) the threaded kernel consumes Ruby
//! messages in the same canonical order, so this kernel is then
//! *bit-identical* to the threaded one — not merely semantics-identical —
//! across thread counts, quantum policies and stealing
//! (docs/DETERMINISM.md, gated by `tests/inbox_order.rs`). While doing so
//! it records how much host work (events) each
//! domain performed in each quantum; [`HostModel`] then computes the
//! wall-clock a parallel run would take on an `h_cores` host via an LPT
//! schedule of each quantum's per-domain work plus a per-barrier
//! synchronisation cost.

use std::sync::atomic::Ordering::Relaxed;
use std::time::Instant;

use crate::sched::plan_next_window;
use crate::sim::time::Tick;

use super::machine::Machine;
use super::result::{KernelCtl, PdesSnapshot, RunOutcome, RunResult, WorkProfile};

pub fn run_virtual(machine: Machine, max_ticks: Tick) -> RunResult {
    run_virtual_ctl(machine, max_ticks, KernelCtl::default()).into_finished()
}

/// The virtual kernel with checkpoint/restore control (docs/CHECKPOINT.md):
/// `ctl.resume_border` skips component init and replans from a restored
/// border; `ctl.checkpoint_at` stops at the first executed border whose
/// `window_end` reaches the requested tick (the snap rule) and returns the
/// machine frozen inside the quiescent span.
pub fn run_virtual_ctl(
    mut machine: Machine,
    max_ticks: Tick,
    ctl: KernelCtl,
) -> RunOutcome {
    let n = machine.n_domains();
    assert!(n >= 2, "virtual kernel requires >= 2 domains");
    let shared = machine.shared.clone();
    let quantum = shared.quantum;
    assert!(quantum > 0 && quantum < Tick::MAX, "virtual requires a quantum");
    let policy = shared.policy;

    let start = Instant::now();
    let mut work = WorkProfile::default();

    let mut window_end = match ctl.resume_border {
        None => {
            let window_end = quantum;
            for dom in machine.domains.iter_mut() {
                dom.init_components(&shared, window_end);
            }
            window_end
        }
        Some(border) => {
            match super::plan_resume_window(&mut machine, border, max_ticks) {
                Some(we) => we,
                None => {
                    // The restored run was already over at its border.
                    return RunOutcome::Finished(finish(
                        machine,
                        start.elapsed().as_nanos() as u64,
                        work,
                        n,
                    ));
                }
            }
        }
    };

    // `--profile`: the same phase timers as the threaded kernel; on one
    // thread the freeze/publish waits are structurally zero, so only the
    // window-exec and border-sync buckets fill.
    let profile = policy.profile;

    loop {
        let t_win = profile.then(Instant::now);
        let mut q_work = vec![0u32; n];
        for (di, dom) in machine.domains.iter_mut().enumerate() {
            q_work[di] =
                dom.run_window(&shared, window_end.min(max_ticks)) as u32;
        }
        if let Some(t) = t_win {
            shared
                .pdes
                .prof_window_ns
                .fetch_add(t.elapsed().as_nanos() as u64, Relaxed);
        }
        work.per_quantum.push(q_work);
        work.window_ends.push(window_end);
        shared.pdes.barriers.fetch_add(1, Relaxed);

        // Same border verdict as the threaded kernel's three-phase
        // protocol: border-sync first (border-ordered inbox merge + the
        // mailbox drain, exactly the threaded kernel's quiescent span),
        // then decide on the post-sync horizon (mailboxes are empty by
        // construction after draining).
        let stop = shared.should_stop();
        let t_sync = profile.then(Instant::now);
        for dom in machine.domains.iter_mut() {
            dom.border_sync(&shared, window_end);
        }
        let horizon = machine
            .domains
            .iter_mut()
            .map(|d| d.next_tick())
            .min()
            .unwrap_or(Tick::MAX);
        if let Some(t) = t_sync {
            shared
                .pdes
                .prof_border_sync_ns
                .fetch_add(t.elapsed().as_nanos() as u64, Relaxed);
        }
        if stop || horizon == Tick::MAX || window_end >= max_ticks {
            break;
        }
        // Snap rule (checked strictly after the stop verdict, so a run
        // that terminates first finishes normally): the first executed
        // border whose `window_end` reaches the requested tick is the
        // checkpoint border. The machine is frozen here, inside the
        // quiescent span — after `border_sync`, before the next plan.
        if let Some(at) = ctl.checkpoint_at {
            if window_end >= at {
                let host_ns = start.elapsed().as_nanos() as u64;
                let result = finish_ref(&machine, host_ns, work, n);
                return RunOutcome::Checkpointed {
                    machine,
                    border: window_end,
                    result,
                };
            }
        }
        // Identical border plan as the threaded kernel: the quantum policy
        // may leap over windows that provably contain no events. The leap
        // target is clamped to the run cutoff — windows past max_ticks are
        // never executed by any policy, so they must not count as skipped.
        let plan = plan_next_window(
            policy.quantum_policy,
            window_end,
            quantum,
            horizon.min(max_ticks.saturating_sub(1)),
        );
        shared.pdes.quanta_skipped.fetch_add(plan.skipped_quanta, Relaxed);
        window_end = plan.window_end;
    }

    let host_ns = start.elapsed().as_nanos() as u64;
    RunOutcome::Finished(finish(machine, host_ns, work, n))
}

fn finish(machine: Machine, host_ns: u64, work: WorkProfile, n: usize) -> RunResult {
    finish_ref(&machine, host_ns, work, n)
}

fn finish_ref(
    machine: &Machine,
    host_ns: u64,
    work: WorkProfile,
    n: usize,
) -> RunResult {
    RunResult {
        sim_ticks: machine.sim_ticks(),
        events: machine.events_executed(),
        host_ns,
        stats: machine.collect_stats(),
        pdes: PdesSnapshot::from_shared(&machine.shared),
        work: Some(work),
        n_domains: n,
    }
}

/// Models an `h_cores` simulation host executing a recorded work profile.
#[derive(Debug, Clone, Copy)]
pub struct HostModel {
    /// Host threads available (the paper's Ryzen 3990x: 64 cores).
    pub h_cores: usize,
    /// Host cost of executing one event, ns. Calibrate with
    /// [`HostModel::calibrate_cost`] from a measured run.
    pub event_cost_ns: f64,
    /// Per-quantum global-barrier cost, ns (pthread barrier + cache-line
    /// ping-pong; 2 us is a conservative mid-range figure for 33-129
    /// threads).
    pub barrier_cost_ns: f64,
    /// Model claim-based window work stealing: `true` packs each window's
    /// per-domain work LPT-style onto the host cores (what `--steal`
    /// converges to); `false` models the static `d % h_cores`
    /// domain→thread binding, so a skewed window is bounded by its most
    /// loaded *thread*, not its most loaded domain.
    pub steal: bool,
}

impl Default for HostModel {
    fn default() -> Self {
        HostModel {
            h_cores: 64,
            event_cost_ns: 250.0,
            barrier_cost_ns: 1_000.0,
            steal: true,
        }
    }
}

impl HostModel {
    /// Host model with a thread-count-dependent barrier cost: centralized
    /// sense-reversing barriers cost roughly O(n) cache-line transfers
    /// (~500 ns base + ~25 ns per participating thread).
    pub fn for_threads(h_cores: usize, n_domains: usize) -> Self {
        HostModel {
            h_cores,
            event_cost_ns: 250.0,
            barrier_cost_ns: 500.0 + 25.0 * n_domains as f64,
            steal: true,
        }
    }

    /// Derive the per-event host cost from a measured run.
    pub fn calibrate_cost(&mut self, result: &RunResult) {
        if result.events > 0 {
            self.event_cost_ns = result.host_ns as f64 / result.events as f64;
        }
    }

    /// Makespan (ns) of one quantum's per-domain work on `h_cores` threads.
    ///
    /// With [`HostModel::steal`] the work is packed by a
    /// longest-processing-time-first list schedule (within 4/3 of optimal —
    /// the bound claim-based stealing converges to); without it, domain `d`
    /// is pinned to host core `d % h_cores` like the kernel's static
    /// assignment.
    pub fn quantum_makespan(&self, work_events: &[u32]) -> f64 {
        if work_events.is_empty() {
            return 0.0;
        }
        let mut w: Vec<f64> = work_events
            .iter()
            .map(|&e| e as f64 * self.event_cost_ns)
            .collect();
        if self.h_cores >= w.len() {
            return w.iter().cloned().fold(0.0, f64::max);
        }
        let mut loads = vec![0.0f64; self.h_cores];
        if self.steal {
            w.sort_by(|a, b| b.partial_cmp(a).unwrap());
            for x in w {
                // assign to least-loaded host core
                let (mi, _) = loads
                    .iter()
                    .enumerate()
                    .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap();
                loads[mi] += x;
            }
        } else {
            for (d, x) in w.iter().enumerate() {
                loads[d % self.h_cores] += x;
            }
        }
        loads.iter().cloned().fold(0.0, f64::max)
    }

    /// Modeled wall-clock (ns) of a threaded-parallel run with this profile.
    pub fn parallel_wall_ns(&self, work: &WorkProfile) -> f64 {
        work.per_quantum
            .iter()
            .map(|q| self.quantum_makespan(q) + self.barrier_cost_ns)
            .sum()
    }

    /// Modeled wall-clock (ns) of the serial reference executing
    /// `serial_events` events.
    pub fn serial_wall_ns(&self, serial_events: u64) -> f64 {
        serial_events as f64 * self.event_cost_ns
    }

    /// Modeled speedup of the parallel run vs a serial run with
    /// `serial_events` total events.
    pub fn speedup(&self, serial_events: u64, work: &WorkProfile) -> f64 {
        let par = self.parallel_wall_ns(work);
        if par == 0.0 {
            0.0
        } else {
            self.serial_wall_ns(serial_events) / par
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model(h_cores: usize, event_cost_ns: f64, barrier_cost_ns: f64) -> HostModel {
        HostModel { h_cores, event_cost_ns, barrier_cost_ns, steal: true }
    }

    #[test]
    fn makespan_unlimited_cores_is_max() {
        let m = model(8, 1.0, 0.0);
        assert_eq!(m.quantum_makespan(&[3, 7, 2]), 7.0);
    }

    #[test]
    fn makespan_lpt_packs_two_cores() {
        let m = model(2, 1.0, 0.0);
        // LPT: [8] | [5,4] -> makespan 9
        assert_eq!(m.quantum_makespan(&[5, 8, 4]), 9.0);
    }

    #[test]
    fn steal_beats_static_binding_on_skew() {
        // Domains 0 and 2 carry all the work; statically they share host
        // core 0 (d % 2) while core 1 idles.
        let steal = model(2, 1.0, 0.0);
        let fixed = HostModel { steal: false, ..steal };
        assert_eq!(fixed.quantum_makespan(&[10, 0, 10, 0]), 20.0);
        assert_eq!(steal.quantum_makespan(&[10, 0, 10, 0]), 10.0);
        // On balanced work the two models agree.
        assert_eq!(fixed.quantum_makespan(&[5, 5, 5, 5]), 10.0);
        assert_eq!(steal.quantum_makespan(&[5, 5, 5, 5]), 10.0);
    }

    #[test]
    fn speedup_perfect_balance() {
        let m = model(4, 10.0, 0.0);
        let work = WorkProfile {
            per_quantum: vec![vec![100, 100, 100, 100]],
            ..Default::default()
        };
        // serial: 400 events; parallel: 100 events of critical path
        assert!((m.speedup(400, &work) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn barrier_cost_reduces_speedup() {
        let free = model(4, 10.0, 0.0);
        let costly = model(4, 10.0, 1000.0);
        let work = WorkProfile {
            per_quantum: vec![vec![100, 100, 100, 100]; 10],
            ..Default::default()
        };
        assert!(costly.speedup(4000, &work) < free.speedup(4000, &work));
    }
}
