//! Sense-reversing quantum barrier with abort support.
//!
//! The threaded kernel synchronises all domain threads at every quantum
//! border (Fig. 1b). `std::sync::Barrier` would deadlock the remaining
//! threads if one domain thread panics (poisoned waits), so this barrier
//! adds an abort path: a panicking thread calls [`QuantumBarrier::abort`]
//! and every current and future waiter returns `Outcome::Aborted`
//! immediately.

use std::sync::atomic::{AtomicBool, Ordering::SeqCst};
use std::sync::{Condvar, Mutex};

pub struct QuantumBarrier {
    n: usize,
    state: Mutex<State>,
    cv: Condvar,
    aborted: AtomicBool,
}

struct State {
    count: usize,
    generation: u64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    /// Last thread to arrive in this generation.
    Leader,
    Follower,
    /// A peer aborted (panicked); stop immediately.
    Aborted,
}

impl QuantumBarrier {
    pub fn new(n: usize) -> Self {
        QuantumBarrier {
            n,
            state: Mutex::new(State { count: 0, generation: 0 }),
            cv: Condvar::new(),
            aborted: AtomicBool::new(false),
        }
    }

    pub fn wait(&self) -> Outcome {
        if self.aborted.load(SeqCst) {
            return Outcome::Aborted;
        }
        let mut st = self.state.lock().unwrap();
        st.count += 1;
        if st.count == self.n {
            st.count = 0;
            st.generation += 1;
            self.cv.notify_all();
            return Outcome::Leader;
        }
        let gen = st.generation;
        loop {
            st = self.cv.wait(st).unwrap();
            if self.aborted.load(SeqCst) {
                return Outcome::Aborted;
            }
            if st.generation != gen {
                return Outcome::Follower;
            }
        }
    }

    /// Release every waiter with `Aborted`; all future waits abort too.
    pub fn abort(&self) {
        self.aborted.store(true, SeqCst);
        let _guard = self.state.lock().unwrap();
        self.cv.notify_all();
    }

    pub fn is_aborted(&self) -> bool {
        self.aborted.load(SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn all_threads_pass_each_generation() {
        let b = QuantumBarrier::new(4);
        let leaders = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..100 {
                        if b.wait() == Outcome::Leader {
                            leaders.fetch_add(1, SeqCst);
                        }
                    }
                });
            }
        });
        assert_eq!(leaders.load(SeqCst), 100, "exactly one leader per round");
    }

    #[test]
    fn abort_releases_waiters() {
        let b = QuantumBarrier::new(3);
        std::thread::scope(|s| {
            let h1 = s.spawn(|| b.wait());
            let h2 = s.spawn(|| b.wait());
            std::thread::sleep(std::time::Duration::from_millis(20));
            b.abort();
            assert_eq!(h1.join().unwrap(), Outcome::Aborted);
            assert_eq!(h2.join().unwrap(), Outcome::Aborted);
        });
        assert_eq!(b.wait(), Outcome::Aborted, "future waits abort too");
    }
}
