//! The machine: all time domains plus the shared state of one run.
//!
//! Built by [`MachineBuilder`]; consumed by one of the kernels in
//! [`crate::pdes`]. Partitioning follows §4.1 of the paper: domain `i` holds
//! core `i` and its private resources, domain `N` holds everything shared.

use std::sync::Arc;

use crate::sched::{BucketShape, QueueKind, RunPolicy, SchedQueue, Scheduler};
use crate::sim::component::Component;
use crate::sim::ids::{CompId, DomainId};
use crate::sim::shared::SharedState;
use crate::sim::stats::StatSink;
use crate::sim::time::Tick;

use super::domain::Domain;

pub struct Machine {
    pub domains: Vec<Domain>,
    pub shared: Arc<SharedState>,
}

impl Machine {
    pub fn n_domains(&self) -> usize {
        self.domains.len()
    }

    /// Final simulated time: the maximum local time over all domains.
    pub fn sim_ticks(&self) -> Tick {
        self.domains.iter().map(|d| d.now).max().unwrap_or(0)
    }

    /// Total events executed across all domains.
    pub fn events_executed(&self) -> u64 {
        self.domains.iter().map(|d| d.eq.executed()).sum()
    }

    pub fn collect_stats(&self) -> StatSink {
        let mut sink = StatSink::new();
        for d in &self.domains {
            d.collect_stats(&mut sink);
        }
        sink
    }
}

/// Incrementally builds the component arena and domain partition.
pub struct MachineBuilder {
    domains: Vec<Domain>,
    locate: Vec<(DomainId, u32)>,
    n_cores: u32,
    quantum: Tick,
    queue: QueueKind,
    shape: BucketShape,
    policy: RunPolicy,
}

impl MachineBuilder {
    /// `n_domains` scheduler queues; `quantum == Tick::MAX` disables
    /// windowing (the serial reference configuration uses exactly one
    /// domain). Queues default to [`QueueKind::default`]; override with
    /// [`MachineBuilder::set_queue`] before components schedule anything.
    pub fn new(n_domains: usize, quantum: Tick) -> Self {
        let queue = QueueKind::default();
        MachineBuilder {
            domains: (0..n_domains)
                .map(|i| Domain::new(DomainId(i as u32), queue))
                .collect(),
            locate: Vec::new(),
            n_cores: 0,
            quantum,
            queue,
            shape: BucketShape::default(),
            policy: RunPolicy::default(),
        }
    }

    /// Select the border policy (adaptive quantum, work stealing, thread
    /// count) for the windowed kernels. Defaults to the paper's behaviour:
    /// fixed quantum, no stealing, one thread per domain.
    pub fn set_policy(&mut self, policy: RunPolicy) {
        self.policy = policy;
    }

    pub fn policy(&self) -> RunPolicy {
        self.policy
    }

    /// Select the event-queue implementation for every domain. Must be
    /// called before `finish` (queues are empty until component init).
    pub fn set_queue(&mut self, kind: QueueKind) {
        self.queue = kind;
        self.rebuild_queues();
    }

    /// Select the calendar geometry for [`QueueKind::Bucket`] domains
    /// (`--bucket-width` / `--bucket-slots`). Like `set_queue`, must be
    /// called before components schedule anything.
    pub fn set_bucket_shape(&mut self, shape: BucketShape) {
        self.shape = shape;
        self.rebuild_queues();
    }

    fn rebuild_queues(&mut self) {
        for d in &mut self.domains {
            debug_assert!(
                d.eq.is_empty(),
                "queue reconfigured after events scheduled"
            );
            d.eq = SchedQueue::with_shape(self.queue, self.shape);
        }
    }

    pub fn queue_kind(&self) -> QueueKind {
        self.queue
    }

    /// Reserve the id a component will get when added next.
    pub fn next_id(&self) -> CompId {
        CompId(self.locate.len() as u32)
    }

    /// Add a component to `domain`, returning its global id.
    pub fn add(&mut self, domain: DomainId, comp: Box<dyn Component>) -> CompId {
        let id = self.next_id();
        let d = &mut self.domains[domain.index()];
        d.comps.push(comp);
        d.comp_ids.push(id);
        self.locate.push((domain, (d.comps.len() - 1) as u32));
        id
    }

    /// Declare the number of simulated cores (for run-termination counting
    /// and the workload barrier).
    pub fn set_cores(&mut self, n: u32) {
        self.n_cores = n;
    }

    pub fn quantum(&self) -> Tick {
        self.quantum
    }

    pub fn finish(self) -> Machine {
        let mut state = SharedState::new(
            self.locate,
            self.domains.len(),
            self.quantum,
            self.n_cores,
        );
        state.policy = self.policy;
        let shared = Arc::new(state);
        shared.wl_barrier.state.lock().unwrap().participants = self.n_cores;
        Machine { domains: self.domains, shared }
    }
}
