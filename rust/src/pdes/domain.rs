//! A time domain: one scheduler queue plus the components it owns.
//!
//! All three kernels (serial, threaded-parallel, virtual-parallel) drive
//! domains through the same [`Domain::run_window`] loop, so the model code
//! paths are identical — only synchronisation differs.

use std::sync::atomic::Ordering::Relaxed;
use std::time::Instant;

use crate::sched::{QueueKind, SchedQueue, Scheduler};
use crate::sim::component::{Component, Ctx};
use crate::sim::event::Event;
use crate::sim::ids::{CompId, DomainId};
use crate::sim::shared::SharedState;
use crate::sim::stats::StatSink;
use crate::sim::time::Tick;

pub struct Domain {
    pub id: DomainId,
    pub eq: SchedQueue,
    /// Components owned by this domain, dense local index.
    pub comps: Vec<Box<dyn Component>>,
    /// Global ids matching `comps` (for dispatch assertions / stats).
    pub comp_ids: Vec<CompId>,
    /// Local simulated time: tick of the last executed event.
    pub now: Tick,
    /// Reusable scratch for the border mailbox drain — steady state
    /// injects without allocating ([`Domain::drain_injections`]).
    inject_scratch: Vec<Event>,
}

impl Domain {
    pub fn new(id: DomainId, queue: QueueKind) -> Self {
        Domain {
            id,
            eq: SchedQueue::new(queue),
            comps: Vec::new(),
            comp_ids: Vec::new(),
            now: 0,
            inject_scratch: Vec::new(),
        }
    }

    /// Call `init` on every component (schedules the initial events).
    pub fn init_components(&mut self, shared: &SharedState, window_end: Tick) {
        let Domain { eq, comps, comp_ids, id, .. } = self;
        for (local, comp) in comps.iter_mut().enumerate() {
            let cid = comp_ids[local];
            let mut ctx = Ctx::new(0, *id, window_end, eq, shared, cid);
            comp.init(&mut ctx);
        }
    }

    /// Execute all events strictly before `window_end`.
    ///
    /// Returns the number of events executed (the per-quantum host-work
    /// proxy used by the virtual host model).
    pub fn run_window(&mut self, shared: &SharedState, window_end: Tick) -> u64 {
        let mut executed = 0u64;
        let Domain { eq, comps, comp_ids, id, now, .. } = self;
        while let Some(ev) = eq.pop_before(window_end) {
            debug_assert!(ev.tick >= *now, "time must not go backwards");
            *now = ev.tick;
            let (dom, local) = shared.locate[ev.target.index()];
            debug_assert_eq!(dom, *id, "event routed to wrong domain");
            debug_assert_eq!(comp_ids[local as usize], ev.target);
            let comp = &mut comps[local as usize];
            let mut ctx =
                Ctx::new(ev.tick, *id, window_end, eq, shared, ev.target);
            comp.handle(ev.kind, &mut ctx);
            executed += 1;
        }
        executed
    }

    /// Merge events other domains injected for us. Only called at quantum
    /// borders while all producers are parked at the barrier (the
    /// [`crate::sched::Mailbox`] single-consumer contract).
    pub fn drain_injections(&mut self, shared: &SharedState) {
        shared.injectors[self.id.index()].drain_into(&mut self.inject_scratch);
        for ev in self.inject_scratch.drain(..) {
            self.eq.insert(ev);
        }
    }

    /// Full quantum-border synchronisation for this domain, executed
    /// inside the quiescent span of the border protocol (every producer
    /// parked at the freeze barrier):
    ///
    /// 1. Under the border-staged protocols (`--inbox-order border` /
    ///    `--xbar-arb border`), run every owned component's
    ///    [`Component::border_merge`] hook: Ruby consumers merge their
    ///    staged cross-domain deliveries in canonical order and arm their
    ///    wakeups; the crossbar arbiter grants the window's staged layer
    ///    requests (each hook gates itself on its own policy knob, so
    ///    e.g. `--inbox-order host --xbar-arb border` arbitrates layers
    ///    without staging messages).
    /// 2. Drain the cross-domain event mailbox ([`Self::drain_injections`]).
    ///
    /// The fixed order (merges in component order, then the sorted mailbox
    /// drain) makes the queue's sequence-number assignment — and therefore
    /// same-`(tick, prio)` tie-breaking — identical across kernels and
    /// thread counts. Callers must publish this domain's `next_tick` only
    /// *after* `border_sync`, so merged wakeups and granted deliveries
    /// count towards the horizon and staged traffic is never dropped by a
    /// quiescent verdict.
    pub fn border_sync(&mut self, shared: &SharedState, border: Tick) {
        if shared.policy.border_staging() {
            let t0 = Instant::now();
            let Domain { eq, comps, comp_ids, id, .. } = self;
            for (local, comp) in comps.iter_mut().enumerate() {
                let mut ctx = Ctx::new(
                    border,
                    *id,
                    border,
                    eq,
                    shared,
                    comp_ids[local],
                );
                comp.border_merge(&mut ctx);
            }
            shared
                .pdes
                .inbox_merge_ns
                .fetch_add(t0.elapsed().as_nanos() as u64, Relaxed);
        }
        self.drain_injections(shared);
    }

    /// Next pending event tick (`Tick::MAX` if idle).
    pub fn next_tick(&mut self) -> Tick {
        self.eq.next_tick().unwrap_or(Tick::MAX)
    }

    /// Collect statistics from all owned components.
    pub fn collect_stats(&self, sink: &mut StatSink) {
        for comp in &self.comps {
            sink.with_prefix(comp.name());
            comp.stats(sink);
        }
    }
}
