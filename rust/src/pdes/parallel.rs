//! The threaded PDES kernel (parti-gem5 proper, Fig. 1b), extended with an
//! adaptive quantum and claim-based window work stealing.
//!
//! Domains are **work items**, host threads are **executors**. With the
//! default policy there is one thread per domain and each thread runs its
//! own domain every window (the paper's configuration); with
//! `RunPolicy::threads < n_domains` the host is oversubscribed and each
//! thread runs several domains per window; with `RunPolicy::steal` the
//! per-window domain→thread binding goes through a
//! [`crate::sched::ClaimList`], so a thread whose claims finish early
//! adopts the windows of the most-loaded remaining domains instead of
//! idling at the freeze barrier. A claim hands a whole domain (its movable
//! `SchedQueue` plus components) to exactly one thread, so stealing adds
//! no nondeterminism beyond the kernel's pre-existing intra-window host
//! timing (paper §6) — see `sched/steal.rs` for the argument.
//!
//! Within a window, domains execute their local event queues freely;
//! cross-domain schedules go through the lock-free mailboxes with the
//! postpone-to-border rule (see [`crate::sim::component::Ctx`]).
//!
//! Each border runs a **three-phase** protocol:
//!
//! 1. **Freeze** barrier — every thread has finished its claims; no queue
//!    or mailbox mutates past this point. Draining before this barrier
//!    would race with producers still inside the window (and made the old
//!    kernel's drain *batching* host-timing-dependent: a fast thread could
//!    start its next window and push while a slow thread was still
//!    draining). With the freeze in place, every mailbox drain sees exactly
//!    the events of the closed window — the drain-sort is deterministic and
//!    the [`crate::sched::Mailbox`] can reclaim fully-consumed segments
//!    with no epochs.
//! 2. Inside the quiescent span each thread runs the border sync of its
//!    *statically* assigned domains (`d % n_threads` — one consumer per
//!    mailbox and one merger per inbox per border, regardless of how the
//!    window claims were assigned): the border-ordered Ruby inbox merge
//!    ([`crate::pdes::domain::Domain::border_sync`], canonical
//!    `(arrival, sender_domain, seq)` order under `--inbox-order border`)
//!    followed by the mailbox drain — then publishes the post-sync
//!    `next_tick`s; the **publish** barrier makes all of them visible.
//!    Merging before publishing is what lets staged Ruby traffic count
//!    towards the horizon, so a quiescent verdict can never drop it.
//! 3. The leader of the publish barrier computes the verdict (stop flag /
//!    global quiescence / max-ticks) and — when continuing — the next
//!    `window_end` via [`crate::sched::plan_next_window`] (leaping dead
//!    windows under `--quantum-policy horizon|hybrid`) plus the next claim
//!    order (heaviest domain first), while the others wait at the
//!    **verdict** barrier; after it, everyone reads the same verdict and
//!    either continues into the planned window or breaks. (Quiescence is
//!    simply "all post-drain next_ticks are `Tick::MAX`" — mailboxes are
//!    empty by construction.)
//!
//! A panic inside a domain (a model bug) aborts the barrier so the
//! remaining threads exit instead of deadlocking; the panic is re-thrown
//! on the caller thread.

use std::sync::atomic::Ordering::{Acquire, Relaxed, Release};
use std::sync::atomic::{AtomicU32, AtomicU64, AtomicU8};
use std::sync::Mutex;
use std::time::Instant;

use crate::sched::{plan_next_window, ClaimList, Outcome, TreeBarrier};
use crate::sim::time::Tick;
use crate::util::CachePadded;

use super::domain::Domain;
use super::machine::Machine;
use super::result::{KernelCtl, PdesSnapshot, RunOutcome, RunResult};

const VERDICT_CONTINUE: u8 = 0;
const VERDICT_STOP: u8 = 1;
/// The verdict leader saw the checkpoint border (snap rule hit): every
/// thread breaks out of the window loop with its domain frozen inside the
/// quiescent span, exactly as for a stop — but the caller gets the machine
/// back for serialization instead of a finished result.
const VERDICT_CHECKPOINT: u8 = 2;

pub fn run_parallel(machine: Machine, max_ticks: Tick) -> RunResult {
    run_parallel_ctl(machine, max_ticks, KernelCtl::default()).into_finished()
}

/// The threaded kernel with checkpoint/restore control: semantics identical
/// to [`run_virtual_ctl`](super::virtual_host::run_virtual_ctl) — same snap
/// rule, same resume plan — so under the border-ordered protocols the
/// checkpoint bytes are producer-kernel invariant (docs/CHECKPOINT.md).
pub fn run_parallel_ctl(
    mut machine: Machine,
    max_ticks: Tick,
    ctl: KernelCtl,
) -> RunOutcome {
    let n = machine.n_domains();
    assert!(n >= 2, "parallel kernel requires >= 2 domains");
    let shared = machine.shared.clone();
    let quantum = shared.quantum;
    assert!(quantum > 0 && quantum < Tick::MAX, "parallel requires a quantum");
    let policy = shared.policy;
    let n_threads =
        if policy.threads == 0 { n } else { policy.threads.min(n) };

    let initial_window_end = match ctl.resume_border {
        None => {
            // Component init is deterministic and single-threaded here (it
            // was per-domain-thread before; the scheduled events are
            // identical).
            for dom in machine.domains.iter_mut() {
                dom.init_components(&shared, quantum);
            }
            quantum
        }
        Some(border) => {
            match super::plan_resume_window(&mut machine, border, max_ticks) {
                Some(we) => we,
                None => {
                    // The restored run was already over at its border.
                    return RunOutcome::Finished(RunResult {
                        sim_ticks: machine.sim_ticks(),
                        events: machine.events_executed(),
                        host_ns: 0,
                        stats: machine.collect_stats(),
                        pdes: PdesSnapshot::from_shared(&machine.shared),
                        work: None,
                        n_domains: n,
                    });
                }
            }
        }
    };

    // Domains become claimable work items. The mutexes are uncontended by
    // construction — claims and the static drain partition each hand a
    // domain to exactly one thread at a time — they only make the handoff
    // safe Rust.
    let slots: Vec<Mutex<Domain>> = std::mem::take(&mut machine.domains)
        .into_iter()
        .map(Mutex::new)
        .collect();

    let barrier = TreeBarrier::new(n_threads);
    // Per-domain hot words are cache-line padded: at every border all
    // threads publish into `next_ticks` (and under `--steal` into `loads`)
    // at once, and unpadded AtomicU64s would pack eight domains onto one
    // line — pure false sharing on the hottest synchronisation path.
    let next_ticks: Vec<CachePadded<AtomicU64>> =
        (0..n).map(|_| CachePadded::new(AtomicU64::new(0))).collect();
    // Events each domain executed in the closed window: the load metric
    // for the deterministic victim order.
    let loads: Vec<CachePadded<AtomicU32>> =
        (0..n).map(|_| CachePadded::new(AtomicU32::new(0))).collect();
    let claims = ClaimList::identity(n);
    let verdict = AtomicU8::new(VERDICT_CONTINUE);
    // Written by the verdict leader, read by everyone after the verdict
    // barrier (which provides the ordering).
    let next_window_end = AtomicU64::new(initial_window_end);
    // Border the checkpoint verdict froze the machine at (leader-written,
    // read after the scope joins).
    let ckpt_border = AtomicU64::new(0);

    let start = Instant::now();

    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for ti in 0..n_threads {
            let shared = &shared;
            let barrier = &barrier;
            let next_ticks = &next_ticks;
            let loads = &loads;
            let claims = &claims;
            let verdict = &verdict;
            let next_window_end = &next_window_end;
            let ckpt_border = &ckpt_border;
            let slots = &slots;
            handles.push(scope.spawn(move || {
                let body = std::panic::AssertUnwindSafe(|| {
                    let mut w = barrier.waiter(ti);
                    let mut window_end = initial_window_end;
                    // `--profile`: per-phase wall breakdowns, summed over
                    // threads into PdesStats. Host-side observation only —
                    // no simulation decision reads these, so determinism
                    // is untouched (gated by tests/perf_identity.rs).
                    let profile = policy.profile;
                    loop {
                        // Window: execute claimed domains.
                        let t_win = profile.then(Instant::now);
                        if policy.steal {
                            while let Some(d) = claims.claim() {
                                let mut dom = slots[d].lock().unwrap();
                                let ex = dom
                                    .run_window(shared, window_end.min(max_ticks));
                                loads[d].store(ex as u32, Relaxed);
                                if d % n_threads != ti {
                                    shared.pdes.steals.fetch_add(1, Relaxed);
                                    shared
                                        .pdes
                                        .stolen_events
                                        .fetch_add(ex, Relaxed);
                                }
                            }
                        } else {
                            // Static binding: loads are only consumed by
                            // the steal replanner, so don't record them.
                            let mut d = ti;
                            while d < n {
                                let mut dom = slots[d].lock().unwrap();
                                dom.run_window(shared, window_end.min(max_ticks));
                                d += n_threads;
                            }
                        }

                        if let Some(t) = t_win {
                            shared.pdes.prof_window_ns.fetch_add(
                                t.elapsed().as_nanos() as u64,
                                Relaxed,
                            );
                        }

                        // Phase 1: freeze — all claims finished, no
                        // producer touches any mailbox past this point.
                        let t_frz = profile.then(Instant::now);
                        match barrier.wait(&mut w) {
                            Outcome::Aborted => return,
                            Outcome::Leader => {
                                shared.pdes.barriers.fetch_add(1, Relaxed);
                            }
                            Outcome::Follower => {}
                        }
                        if let Some(t) = t_frz {
                            shared.pdes.prof_freeze_wait_ns.fetch_add(
                                t.elapsed().as_nanos() as u64,
                                Relaxed,
                            );
                        }

                        // Quiescent span: for the statically assigned
                        // domains, merge the border-ordered inbox stages
                        // and drain the mailboxes (one consumer per
                        // domain per border — the static `d % T`
                        // partition, independent of window claims), then
                        // publish the post-sync horizons. The merge must
                        // precede the publish so staged Ruby traffic
                        // counts towards quiescence.
                        let t_sync = profile.then(Instant::now);
                        let mut d = ti;
                        while d < n {
                            let mut dom = slots[d].lock().unwrap();
                            dom.border_sync(shared, window_end);
                            next_ticks[d].store(dom.next_tick(), Release);
                            d += n_threads;
                        }
                        if let Some(t) = t_sync {
                            shared.pdes.prof_border_sync_ns.fetch_add(
                                t.elapsed().as_nanos() as u64,
                                Relaxed,
                            );
                        }

                        // Phase 2: publish — all post-drain next_ticks are
                        // now visible; the leader computes the verdict and
                        // the next window plan while the others park in
                        // phase 3. (The profile bucket covers both waits
                        // plus the leader's planning work.)
                        let t_pub = profile.then(Instant::now);
                        match barrier.wait(&mut w) {
                            Outcome::Aborted => return,
                            Outcome::Leader => {
                                let mut horizon = Tick::MAX;
                                for t in next_ticks.iter() {
                                    horizon = horizon.min(t.load(Acquire));
                                }
                                let quiescent = horizon == Tick::MAX;
                                let stop = shared.should_stop()
                                    || quiescent
                                    || window_end >= max_ticks;
                                // Snap rule, strictly after the stop
                                // verdict (same order as the virtual
                                // kernel): freeze at the first executed
                                // border reaching the requested tick.
                                let ckpt = !stop
                                    && ctl
                                        .checkpoint_at
                                        .is_some_and(|at| window_end >= at);
                                if ckpt {
                                    ckpt_border.store(window_end, Relaxed);
                                }
                                if !stop && !ckpt {
                                    // Clamp the leap target to the run
                                    // cutoff: windows past max_ticks are
                                    // never executed by any policy, so
                                    // they must not count as skipped.
                                    let plan = plan_next_window(
                                        policy.quantum_policy,
                                        window_end,
                                        quantum,
                                        horizon
                                            .min(max_ticks.saturating_sub(1)),
                                    );
                                    shared
                                        .pdes
                                        .quanta_skipped
                                        .fetch_add(plan.skipped_quanta, Relaxed);
                                    next_window_end
                                        .store(plan.window_end, Relaxed);
                                    if policy.steal {
                                        let ld: Vec<u32> = loads
                                            .iter()
                                            .map(|l| l.load(Relaxed))
                                            .collect();
                                        claims.replan(&ld);
                                    }
                                }
                                verdict.store(
                                    if stop {
                                        VERDICT_STOP
                                    } else if ckpt {
                                        VERDICT_CHECKPOINT
                                    } else {
                                        VERDICT_CONTINUE
                                    },
                                    Release,
                                );
                            }
                            Outcome::Follower => {}
                        }

                        // Phase 3: verdict — everyone reads the same one.
                        if barrier.wait(&mut w) == Outcome::Aborted {
                            return;
                        }
                        if let Some(t) = t_pub {
                            shared.pdes.prof_publish_wait_ns.fetch_add(
                                t.elapsed().as_nanos() as u64,
                                Relaxed,
                            );
                        }
                        if verdict.load(Acquire) != VERDICT_CONTINUE {
                            break;
                        }
                        window_end = next_window_end.load(Relaxed);
                    }
                });
                if let Err(payload) = std::panic::catch_unwind(body) {
                    barrier.abort();
                    std::panic::resume_unwind(payload);
                }
            }));
        }
        let mut panic_payload = None;
        for h in handles {
            if let Err(p) = h.join() {
                panic_payload = Some(p);
            }
        }
        if let Some(p) = panic_payload {
            std::panic::resume_unwind(p);
        }
    });

    machine.domains = slots
        .into_iter()
        .map(|m| m.into_inner().unwrap_or_else(|p| p.into_inner()))
        .collect();

    let host_ns = start.elapsed().as_nanos() as u64;
    let result = RunResult {
        sim_ticks: machine.sim_ticks(),
        events: machine.events_executed(),
        host_ns,
        stats: machine.collect_stats(),
        pdes: PdesSnapshot::from_shared(&machine.shared),
        work: None,
        n_domains: n,
    };
    if verdict.load(Relaxed) == VERDICT_CHECKPOINT {
        RunOutcome::Checkpointed {
            machine,
            border: ckpt_border.load(Relaxed),
            result,
        }
    } else {
        RunOutcome::Finished(result)
    }
}
