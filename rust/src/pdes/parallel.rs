//! The threaded PDES kernel (parti-gem5 proper, Fig. 1b).
//!
//! One host thread per time domain; a global combining-tree barrier
//! ([`crate::sched::TreeBarrier`]) at every border. Within a window,
//! domains execute their local event queues freely; cross-domain schedules
//! go through the lock-free mailboxes with the postpone-to-border rule
//! (see [`crate::sim::component::Ctx`]).
//!
//! Each border runs a **three-phase** protocol:
//!
//! 1. **Freeze** barrier — every thread has finished its window; no queue
//!    or mailbox mutates past this point. Draining before this barrier
//!    would race with producers still inside the window (and made the old
//!    kernel's drain *batching* host-timing-dependent: a fast thread could
//!    start its next window and push while a slow thread was still
//!    draining). With the freeze in place, every mailbox drain sees exactly
//!    the events of the closed window — the drain-sort is deterministic and
//!    the [`crate::sched::Mailbox`] can reclaim fully-consumed segments
//!    with no epochs.
//! 2. Every thread drains its own mailbox (single consumer) and publishes
//!    its post-drain `next_tick`; the **publish** barrier then makes all of
//!    them visible.
//! 3. The leader of the publish barrier computes the verdict (stop flag /
//!    global quiescence / max-ticks) while the others wait at the
//!    **verdict** barrier; after it, everyone reads the same verdict and
//!    either continues or breaks. (Quiescence is simply "all post-drain
//!    next_ticks are `Tick::MAX`" — mailboxes are empty by construction.)
//!
//! A panic inside a domain (a model bug) aborts the barrier so the
//! remaining threads exit instead of deadlocking; the panic is re-thrown
//! on the caller thread.

use std::sync::atomic::Ordering::{Acquire, Relaxed, Release};
use std::sync::atomic::{AtomicU64, AtomicU8};
use std::time::Instant;

use crate::sched::{Outcome, TreeBarrier};
use crate::sim::time::Tick;

use super::machine::Machine;
use super::result::{PdesSnapshot, RunResult};

const VERDICT_CONTINUE: u8 = 0;
const VERDICT_STOP: u8 = 1;

pub fn run_parallel(mut machine: Machine, max_ticks: Tick) -> RunResult {
    let n = machine.n_domains();
    assert!(n >= 2, "parallel kernel requires >= 2 domains");
    let shared = machine.shared.clone();
    let quantum = shared.quantum;
    assert!(quantum > 0 && quantum < Tick::MAX, "parallel requires a quantum");

    let barrier = TreeBarrier::new(n);
    let next_ticks: Vec<AtomicU64> =
        (0..n).map(|_| AtomicU64::new(0)).collect();
    let verdict = AtomicU8::new(VERDICT_CONTINUE);

    let start = Instant::now();

    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for (di, dom) in machine.domains.iter_mut().enumerate() {
            let shared = &shared;
            let barrier = &barrier;
            let next_ticks = &next_ticks;
            let verdict = &verdict;
            handles.push(scope.spawn(move || {
                let body = std::panic::AssertUnwindSafe(|| {
                    let mut w = barrier.waiter(di);
                    let mut window_end = quantum;
                    dom.init_components(shared, window_end);
                    loop {
                        dom.run_window(shared, window_end.min(max_ticks));

                        // Phase 1: freeze — all windows finished, no
                        // producer touches any mailbox past this point.
                        match barrier.wait(&mut w) {
                            Outcome::Aborted => return,
                            Outcome::Leader => {
                                shared.pdes.barriers.fetch_add(1, Relaxed);
                            }
                            Outcome::Follower => {}
                        }

                        // Quiescent span: single-consumer drain, then
                        // publish the post-drain horizon.
                        dom.drain_injections(shared);
                        next_ticks[di].store(dom.next_tick(), Release);

                        // Phase 2: publish — all post-drain next_ticks are
                        // now visible; the leader computes the verdict
                        // while the others park in phase 3.
                        match barrier.wait(&mut w) {
                            Outcome::Aborted => return,
                            Outcome::Leader => {
                                let quiescent = next_ticks
                                    .iter()
                                    .all(|t| t.load(Acquire) == Tick::MAX);
                                let stop = shared.should_stop()
                                    || quiescent
                                    || window_end >= max_ticks;
                                verdict.store(
                                    if stop { VERDICT_STOP } else { VERDICT_CONTINUE },
                                    Release,
                                );
                            }
                            Outcome::Follower => {}
                        }

                        // Phase 3: verdict — everyone reads the same one.
                        if barrier.wait(&mut w) == Outcome::Aborted {
                            return;
                        }
                        if verdict.load(Acquire) == VERDICT_STOP {
                            break;
                        }
                        window_end += quantum;
                    }
                });
                if let Err(payload) = std::panic::catch_unwind(body) {
                    barrier.abort();
                    std::panic::resume_unwind(payload);
                }
            }));
        }
        let mut panic_payload = None;
        for h in handles {
            if let Err(p) = h.join() {
                panic_payload = Some(p);
            }
        }
        if let Some(p) = panic_payload {
            std::panic::resume_unwind(p);
        }
    });

    let host_ns = start.elapsed().as_nanos() as u64;
    RunResult {
        sim_ticks: machine.sim_ticks(),
        events: machine.events_executed(),
        host_ns,
        stats: machine.collect_stats(),
        pdes: PdesSnapshot::from_shared(&machine.shared),
        work: None,
        n_domains: n,
    }
}
