//! The threaded PDES kernel (parti-gem5 proper, Fig. 1b).
//!
//! One host thread per time domain; a global quantum barrier at every
//! border. Within a window, domains execute their local event queues
//! freely; cross-domain schedules go through the injectors with the
//! postpone-to-border rule (see [`crate::sim::component::Ctx`]).
//!
//! Termination uses a two-phase verdict so that every thread exits at the
//! same border (a single-phase check races: a fast thread could drain its
//! injector before a slow thread scans it, making the "all quiescent"
//! verdict non-unanimous and deadlocking the barrier):
//!
//! 1. barrier — every thread has finished its window and published its
//!    `next_tick`; nobody mutates queues.
//! 2. the leader computes the verdict (stop flag / global quiescence /
//!    max-ticks) while the others wait.
//! 3. barrier — everyone reads the same verdict, then drains and either
//!    continues or breaks.
//!
//! A panic inside a domain (a model bug) aborts the barrier so the
//! remaining threads exit instead of deadlocking; the panic is re-thrown
//! on the caller thread.

use std::sync::atomic::{AtomicU64, AtomicU8, Ordering::SeqCst};
use std::time::Instant;

use crate::sim::time::Tick;

use super::barrier::{Outcome, QuantumBarrier};
use super::machine::Machine;
use super::result::{PdesSnapshot, RunResult};

const VERDICT_CONTINUE: u8 = 0;
const VERDICT_STOP: u8 = 1;

pub fn run_parallel(mut machine: Machine, max_ticks: Tick) -> RunResult {
    let n = machine.n_domains();
    assert!(n >= 2, "parallel kernel requires >= 2 domains");
    let shared = machine.shared.clone();
    let quantum = shared.quantum;
    assert!(quantum > 0 && quantum < Tick::MAX, "parallel requires a quantum");

    let barrier = QuantumBarrier::new(n);
    let next_ticks: Vec<AtomicU64> =
        (0..n).map(|_| AtomicU64::new(0)).collect();
    let verdict = AtomicU8::new(VERDICT_CONTINUE);

    let start = Instant::now();

    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for (di, dom) in machine.domains.iter_mut().enumerate() {
            let shared = &shared;
            let barrier = &barrier;
            let next_ticks = &next_ticks;
            let verdict = &verdict;
            handles.push(scope.spawn(move || {
                let body = std::panic::AssertUnwindSafe(|| {
                    let mut window_end = quantum;
                    dom.init_components(shared, window_end);
                    loop {
                        dom.run_window(shared, window_end.min(max_ticks));
                        next_ticks[di].store(dom.next_tick(), SeqCst);

                        // Phase 1: all windows finished, state frozen.
                        match barrier.wait() {
                            Outcome::Aborted => return,
                            Outcome::Leader => {
                                shared.pdes.barriers.fetch_add(1, SeqCst);
                                let quiescent = next_ticks
                                    .iter()
                                    .all(|t| t.load(SeqCst) == Tick::MAX)
                                    && shared
                                        .injectors
                                        .iter()
                                        .all(|i| i.is_empty());
                                let stop = shared.should_stop()
                                    || quiescent
                                    || window_end >= max_ticks;
                                verdict.store(
                                    if stop { VERDICT_STOP } else { VERDICT_CONTINUE },
                                    SeqCst,
                                );
                            }
                            Outcome::Follower => {}
                        }
                        // Phase 2: everyone adopts the leader's verdict.
                        if barrier.wait() == Outcome::Aborted {
                            return;
                        }
                        dom.drain_injections(shared);
                        if verdict.load(SeqCst) == VERDICT_STOP {
                            break;
                        }
                        window_end += quantum;
                    }
                });
                if let Err(payload) = std::panic::catch_unwind(body) {
                    barrier.abort();
                    std::panic::resume_unwind(payload);
                }
            }));
        }
        let mut panic_payload = None;
        for h in handles {
            if let Err(p) = h.join() {
                panic_payload = Some(p);
            }
        }
        if let Some(p) = panic_payload {
            std::panic::resume_unwind(p);
        }
    });

    let host_ns = start.elapsed().as_nanos() as u64;
    RunResult {
        sim_ticks: machine.sim_ticks(),
        events: machine.events_executed(),
        host_ns,
        stats: machine.collect_stats(),
        pdes: PdesSnapshot::from_shared(&machine.shared),
        work: None,
        n_domains: n,
    }
}
