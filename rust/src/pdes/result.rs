//! Outcome of one simulation run, independent of the kernel that produced it.

use std::sync::atomic::Ordering::Relaxed;

use crate::sim::shared::SharedState;
use crate::sim::stats::StatSink;
use crate::sim::time::{ticks_to_seconds, Tick};

/// Per-quantum, per-domain host-work profile (events executed). Only filled
/// by the virtual kernel; feeds the host model (DESIGN.md §3 substitution).
#[derive(Default, Clone)]
pub struct WorkProfile {
    /// `work[q][d]` = events domain `d` executed in quantum `q`.
    pub per_quantum: Vec<Vec<u32>>,
    /// `window_ends[q]` = the `window_end` the quantum policy chose for
    /// window `q` (aligned with `per_quantum`); records every per-window
    /// adaptive-quantum decision of the run.
    pub window_ends: Vec<Tick>,
}

impl WorkProfile {
    pub fn total(&self) -> u64 {
        self.per_quantum
            .iter()
            .flat_map(|q| q.iter().map(|&w| w as u64))
            .sum()
    }
}

/// Snapshot of the PDES artefact counters.
#[derive(Debug, Default, Clone, Copy)]
pub struct PdesSnapshot {
    pub cross_events: u64,
    pub postponed: u64,
    pub tpp_sum: Tick,
    pub barriers: u64,
    /// Dead windows the adaptive quantum policy skipped (deterministic).
    pub quanta_skipped: u64,
    /// Stolen window claims (threaded kernel; host-timing dependent).
    pub steals: u64,
    /// Events executed in stolen claims (host-timing dependent).
    pub stolen_events: u64,
    /// Cross-domain Ruby deliveries staged by the border-ordered handoff
    /// (`--inbox-order border`; deterministic).
    pub inbox_staged: u64,
    /// Staged deliveries the canonical merge moved away from their host
    /// staging position (host-timing dependent on the threaded kernel).
    pub inbox_reordered: u64,
    /// Host nanoseconds spent in the border-staged merge hooks — inbox
    /// merges plus the crossbar grant pass under `--xbar-arb border`
    /// (host-timing dependent, like `host_ns`).
    pub inbox_merge_ns: u64,
    /// IO-crossbar layer requests staged by the border-staged arbitration
    /// (`--xbar-arb border`; deterministic).
    pub xbar_staged: u64,
    /// Border grant decisions deferred on a still-occupied layer
    /// (deterministic; a request waiting k borders counts k times).
    pub xbar_deferred_grants: u64,
    /// Memory ops the workload offered (total trace ops; deterministic).
    pub traffic_offered: u64,
    /// Offered ops accepted to completion by the memory system
    /// (deterministic; `< traffic_offered` when a saturating pattern is
    /// truncated — the offered-vs-accepted backpressure signal).
    pub traffic_accepted: u64,
    /// LSQ-full issue retries — backpressure on offered load
    /// (deterministic).
    pub traffic_retries: u64,
    /// Traffic phases of the workload (`bursty-phase`; deterministic).
    pub traffic_phases: u64,
    /// Ops the O3 pipelines issued (memory or in-LSQ forward;
    /// deterministic, zero under Minor).
    pub issued: u64,
    /// Fetched-but-undispatched ops squashed at workload barriers
    /// (O3; deterministic).
    pub squashed: u64,
    /// O3 dispatch stalls on a full ROB (deterministic).
    pub rob_full_stalls: u64,
    /// O3 dispatch stalls on a full issue queue (deterministic).
    pub iq_full_stalls: u64,
    /// Time-integrated ROB occupancy, Σ entries × ticks over all O3
    /// cores (deterministic).
    pub rob_occupancy_sum: u64,
    /// `--profile`: host ns executing window claims, summed over threads.
    pub prof_window_ns: u64,
    /// `--profile`: host ns waiting at the freeze barrier, summed over
    /// threads.
    pub prof_freeze_wait_ns: u64,
    /// `--profile`: host ns in the border sync, summed over threads.
    pub prof_border_sync_ns: u64,
    /// `--profile`: host ns in the publish+verdict phases, summed over
    /// threads.
    pub prof_publish_wait_ns: u64,
}

impl PdesSnapshot {
    pub fn from_shared(s: &SharedState) -> Self {
        PdesSnapshot {
            cross_events: s.pdes.cross_events.load(Relaxed),
            postponed: s.pdes.postponed.load(Relaxed),
            tpp_sum: s.pdes.tpp_sum.load(Relaxed),
            barriers: s.pdes.barriers.load(Relaxed),
            quanta_skipped: s.pdes.quanta_skipped.load(Relaxed),
            steals: s.pdes.steals.load(Relaxed),
            stolen_events: s.pdes.stolen_events.load(Relaxed),
            inbox_staged: s.pdes.inbox_staged.load(Relaxed),
            inbox_reordered: s.pdes.inbox_reordered.load(Relaxed),
            inbox_merge_ns: s.pdes.inbox_merge_ns.load(Relaxed),
            xbar_staged: s.pdes.xbar_staged.load(Relaxed),
            xbar_deferred_grants: s.pdes.xbar_deferred_grants.load(Relaxed),
            traffic_offered: s.pdes.traffic_offered.load(Relaxed),
            traffic_accepted: s.pdes.traffic_accepted.load(Relaxed),
            traffic_retries: s.pdes.traffic_retries.load(Relaxed),
            traffic_phases: s.pdes.traffic_phases.load(Relaxed),
            issued: s.pdes.issued.load(Relaxed),
            squashed: s.pdes.squashed.load(Relaxed),
            rob_full_stalls: s.pdes.rob_full_stalls.load(Relaxed),
            iq_full_stalls: s.pdes.iq_full_stalls.load(Relaxed),
            rob_occupancy_sum: s.pdes.rob_occupancy_sum.load(Relaxed),
            prof_window_ns: s.pdes.prof_window_ns.load(Relaxed),
            prof_freeze_wait_ns: s.pdes.prof_freeze_wait_ns.load(Relaxed),
            prof_border_sync_ns: s.pdes.prof_border_sync_ns.load(Relaxed),
            prof_publish_wait_ns: s.pdes.prof_publish_wait_ns.load(Relaxed),
        }
    }

    /// True when any `--profile` phase timer fired (profiling was on and
    /// the run reached at least one border).
    pub fn profiled(&self) -> bool {
        self.prof_window_ns
            | self.prof_freeze_wait_ns
            | self.prof_border_sync_ns
            | self.prof_publish_wait_ns
            != 0
    }

    /// Mean host cost of one border's staged-merge hooks (inbox merges
    /// + crossbar grants), in nanoseconds per barrier (the "merge cost
    /// per window" figure of DESIGN.md §6).
    pub fn merge_ns_per_window(&self) -> f64 {
        if self.barriers == 0 {
            0.0
        } else {
            self.inbox_merge_ns as f64 / self.barriers as f64
        }
    }

    /// Mean postponement delay in ticks.
    pub fn tpp_mean(&self) -> f64 {
        if self.postponed == 0 {
            0.0
        } else {
            self.tpp_sum as f64 / self.postponed as f64
        }
    }
}

/// Kernel control block for checkpoint-producing and restored runs
/// (docs/CHECKPOINT.md). Default = an ordinary cold run.
#[derive(Debug, Default, Clone, Copy)]
pub struct KernelCtl {
    /// Resume a machine restored from a snapshot taken at this border:
    /// component init is skipped (the restored queues already hold the
    /// pending events) and the first window is planned from this border
    /// exactly as the producing run would have planned it.
    pub resume_border: Option<Tick>,
    /// Checkpoint request: stop at the first *executed* quantum border
    /// whose `window_end >= checkpoint_at` (the snap rule — mid-window
    /// ticks snap forward deterministically) and hand the machine back
    /// inside the quiescent span. A run that terminates before reaching
    /// the tick finishes normally.
    pub checkpoint_at: Option<Tick>,
}

/// What a windowed kernel handed back: a finished run, or a machine frozen
/// at a quantum border for checkpointing.
pub enum RunOutcome {
    Finished(RunResult),
    /// The kernel stopped at `border` (inside the quiescent span: mailboxes
    /// drained, inbox/xbar stages merged, every component idle between
    /// events). `machine` holds the complete architectural state;
    /// `result` summarises the partial run.
    Checkpointed {
        machine: super::machine::Machine,
        border: Tick,
        result: RunResult,
    },
}

impl RunOutcome {
    /// Unwrap a run that could not have checkpointed.
    pub fn into_finished(self) -> RunResult {
        match self {
            RunOutcome::Finished(r) => r,
            RunOutcome::Checkpointed { .. } => {
                panic!("unexpected checkpoint outcome: none was requested")
            }
        }
    }
}

/// Result of one run.
pub struct RunResult {
    /// Total simulated time.
    pub sim_ticks: Tick,
    /// Events executed across all domains.
    pub events: u64,
    /// Host wall-clock of the run (ns).
    pub host_ns: u64,
    /// All component statistics.
    pub stats: StatSink,
    pub pdes: PdesSnapshot,
    /// Work profile (virtual kernel only).
    pub work: Option<WorkProfile>,
    /// Number of time domains used.
    pub n_domains: usize,
}

impl RunResult {
    pub fn sim_seconds(&self) -> f64 {
        ticks_to_seconds(self.sim_ticks)
    }

    /// Simulated instructions (ops) per second of host time, in MIPS.
    pub fn mips(&self) -> f64 {
        let insts = self.stats.sum_suffix(".committed_ops");
        if self.host_ns == 0 {
            0.0
        } else {
            insts / (self.host_ns as f64 / 1e9) / 1e6
        }
    }

    /// Host events per second.
    pub fn events_per_sec(&self) -> f64 {
        if self.host_ns == 0 {
            0.0
        } else {
            self.events as f64 / (self.host_ns as f64 / 1e9)
        }
    }
}
