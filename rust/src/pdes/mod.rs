//! Parallel discrete-event simulation (the paper's contribution, §3.1/§4).
//!
//! Three interchangeable kernels drive the same [`domain::Domain`] loop:
//!
//! * [`serial::run_serial`] — gem5's reference single-thread DES.
//! * [`parallel::run_parallel`] — parti-gem5: host threads execute time
//!   domains window by window (one thread per domain by default;
//!   oversubscribable and work-stealing via `RunPolicy`), quantum barriers,
//!   postponed cross-domain events.
//! * [`virtual_host::run_virtual`] — identical PDES semantics executed
//!   deterministically on one thread, recording a per-quantum work profile
//!   for the [`virtual_host::HostModel`] speedup estimator (the 64-core-host
//!   substitution, DESIGN.md §3).
//!
//! Both windowed kernels advance `window_end` through the same
//! [`crate::sched::plan_next_window`] border decision, so the adaptive
//! quantum (`--quantum-policy`) is policy-identical — and result-identical,
//! see DESIGN.md §4.4 — across them.
//!
//! Event queues, cross-domain mailboxes, the quantum barrier, the window
//! policy and the claim list live in [`crate::sched`]; every kernel
//! schedules exclusively through that API.

pub mod domain;
pub mod machine;
pub mod parallel;
pub mod result;
pub mod serial;
pub mod virtual_host;

pub use machine::{Machine, MachineBuilder};
pub use parallel::run_parallel;
pub use result::{PdesSnapshot, RunResult, WorkProfile};
pub use serial::run_serial;
pub use virtual_host::{run_virtual, HostModel};
