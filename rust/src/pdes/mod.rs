//! Parallel discrete-event simulation (the paper's contribution, §3.1/§4).
//!
//! Three interchangeable kernels drive the same [`domain::Domain`] loop:
//!
//! * [`serial::run_serial`] — gem5's reference single-thread DES.
//! * [`parallel::run_parallel`] — parti-gem5: one thread per time domain,
//!   quantum barriers, postponed cross-domain events.
//! * [`virtual_host::run_virtual`] — identical PDES semantics executed
//!   deterministically on one thread, recording a per-quantum work profile
//!   for the [`virtual_host::HostModel`] speedup estimator (the 64-core-host
//!   substitution, DESIGN.md §3).
//!
//! Event queues, cross-domain mailboxes and the quantum barrier live in
//! [`crate::sched`]; every kernel schedules exclusively through that API.

pub mod domain;
pub mod machine;
pub mod parallel;
pub mod result;
pub mod serial;
pub mod virtual_host;

pub use machine::{Machine, MachineBuilder};
pub use parallel::run_parallel;
pub use result::{PdesSnapshot, RunResult, WorkProfile};
pub use serial::run_serial;
pub use virtual_host::{run_virtual, HostModel};
