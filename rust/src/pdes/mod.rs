//! Parallel discrete-event simulation (the paper's contribution, §3.1/§4).
//!
//! Three interchangeable kernels drive the same [`domain::Domain`] loop:
//!
//! * [`serial::run_serial`] — gem5's reference single-thread DES.
//! * [`parallel::run_parallel`] — parti-gem5: host threads execute time
//!   domains window by window (one thread per domain by default;
//!   oversubscribable and work-stealing via `RunPolicy`), quantum barriers,
//!   postponed cross-domain events.
//! * [`virtual_host::run_virtual`] — identical PDES semantics executed
//!   deterministically on one thread, recording a per-quantum work profile
//!   for the [`virtual_host::HostModel`] speedup estimator (the 64-core-host
//!   substitution, DESIGN.md §3).
//!
//! Both windowed kernels advance `window_end` through the same
//! [`crate::sched::plan_next_window`] border decision, so the adaptive
//! quantum (`--quantum-policy`) is policy-identical — and result-identical,
//! see DESIGN.md §4.4 — across them.
//!
//! Event queues, cross-domain mailboxes, the quantum barrier, the window
//! policy and the claim list live in [`crate::sched`]; every kernel
//! schedules exclusively through that API.

pub mod domain;
pub mod machine;
pub mod parallel;
pub mod result;
pub mod serial;
pub mod virtual_host;

pub use machine::{Machine, MachineBuilder};
pub use parallel::{run_parallel, run_parallel_ctl};
pub use result::{KernelCtl, PdesSnapshot, RunOutcome, RunResult, WorkProfile};
pub use serial::run_serial;
pub use virtual_host::{run_virtual, run_virtual_ctl, HostModel};

use std::sync::atomic::Ordering::Relaxed;

use crate::sched::plan_next_window;
use crate::sim::time::Tick;

/// Resume prologue shared by both windowed kernels: plan the first window
/// of a machine restored at `border`, exactly as the producing run would
/// have planned it at that border (same policy, same post-sync horizon —
/// the restored queues are bit-identical, so the plan is too). Returns
/// `None` when the restored run is already over (stop flag raised, global
/// quiescence, or the border at/past the cutoff) — the caller finishes
/// without executing a window.
pub fn plan_resume_window(
    machine: &mut Machine,
    border: Tick,
    max_ticks: Tick,
) -> Option<Tick> {
    let shared = machine.shared.clone();
    let stop = shared.should_stop();
    let horizon = machine
        .domains
        .iter_mut()
        .map(|d| d.next_tick())
        .min()
        .unwrap_or(Tick::MAX);
    if stop || horizon == Tick::MAX || border >= max_ticks {
        return None;
    }
    let plan = plan_next_window(
        shared.policy.quantum_policy,
        border,
        shared.quantum,
        horizon.min(max_ticks.saturating_sub(1)),
    );
    shared.pdes.quanta_skipped.fetch_add(plan.skipped_quanta, Relaxed);
    Some(plan.window_end)
}
