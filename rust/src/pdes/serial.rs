//! The reference single-thread DES kernel (gem5's default, Fig. 1a).
//!
//! The machine must have been built with exactly one domain; all events run
//! in strict `(tick, prio, seq)` order, so results are fully deterministic.
//! Speedups in the paper (and in our figures) are measured against this
//! kernel.

use std::time::Instant;

use crate::sched::Scheduler;
use crate::sim::time::Tick;

use super::machine::Machine;
use super::result::{PdesSnapshot, RunResult};

pub fn run_serial(mut machine: Machine, max_ticks: Tick) -> RunResult {
    assert_eq!(
        machine.n_domains(),
        1,
        "serial kernel requires a single-domain machine"
    );
    let shared = machine.shared.clone();
    let start = Instant::now();

    let d = &mut machine.domains[0];
    d.init_components(&shared, Tick::MAX);

    // Run in bounded windows so the stop flag (set by core_done) is observed
    // without checking it on every event.
    const CHECK_EVERY: Tick = 1_000_000; // 1 us of simulated time
    let mut horizon = CHECK_EVERY;
    loop {
        d.run_window(&shared, horizon.min(max_ticks));
        if shared.should_stop() || horizon >= max_ticks || d.eq.is_empty() {
            break;
        }
        horizon += CHECK_EVERY;
    }

    let host_ns = start.elapsed().as_nanos() as u64;
    RunResult {
        sim_ticks: machine.sim_ticks(),
        events: machine.events_executed(),
        host_ns,
        stats: machine.collect_stats(),
        pdes: PdesSnapshot::from_shared(&machine.shared),
        work: None,
        n_domains: 1,
    }
}
