"""Pallas STREAM triad kernel: a[i] = b[i] + scalar * c[i].

The payload of the STREAM bandwidth benchmark (www.cs.virginia.edu/stream).
Purely bandwidth-bound — one FMA per 12 loaded/stored bytes — which is the
point: in the paper STREAM is the workload that maximises off-chip traffic
and therefore minimises PDES speedup. The Rust coordinator replays the
corresponding addrgen trace; this kernel provides the numeric ground truth.

The scalar arrives as an f32[1] SMEM-style block (broadcast in-kernel).
interpret=True for CPU PJRT (see addrgen.py).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TRIAD_BLOCK = 2048


def _triad_kernel(b_ref, c_ref, s_ref, a_ref):
    a_ref[...] = b_ref[...] + s_ref[0] * c_ref[...]


@jax.jit
def stream_triad(b, c, scalar):
    """b, c: f32[n] (n multiple of TRIAD_BLOCK); scalar: f32[1] -> f32[n]."""
    n = b.shape[0]
    if n % TRIAD_BLOCK != 0:
        raise ValueError(f"n={n} must be a multiple of {TRIAD_BLOCK}")
    grid = (n // TRIAD_BLOCK,)
    spec = pl.BlockSpec((TRIAD_BLOCK,), lambda i: (i,))
    return pl.pallas_call(
        _triad_kernel,
        grid=grid,
        in_specs=[spec, spec, pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(b, c, scalar)
