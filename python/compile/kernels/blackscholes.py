"""Pallas Black-Scholes kernel — the PARSEC ``blackscholes`` payload.

European call/put option pricing with the Abramowitz & Stegun 26.2.17
polynomial CND, exactly as PARSEC's C implementation. The simulated cores in
the Rust coordinator "execute" blackscholes by streaming the trace produced
by ``addrgen``; this kernel produces the numeric results the example binaries
use to verify functional end-to-end correctness (data written through the
simulated coherent memory equals this kernel's output).

Tiling: 1-D grid over blocks of BS_BLOCK lanes; five f32 input blocks + two
f32 output blocks = 28 KiB of VMEM per step. Elementwise/VPU-bound (exp, log,
sqrt) — no MXU use. interpret=True for CPU PJRT (see addrgen.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BS_BLOCK = 1024

_A1 = 0.31938153
_A2 = -0.356563782
_A3 = 1.781477937
_A4 = -1.821255978
_A5 = 1.330274429
_INV_SQRT_2PI = 0.3989422804014327


def _cnd(x):
    l = jnp.abs(x)
    k = 1.0 / (1.0 + 0.2316419 * l)
    poly = k * (_A1 + k * (_A2 + k * (_A3 + k * (_A4 + k * _A5))))
    w = 1.0 - _INV_SQRT_2PI * jnp.exp(-l * l / 2.0) * poly
    return jnp.where(x < 0.0, 1.0 - w, w)


def _bs_kernel(spot_ref, strike_ref, rate_ref, vol_ref, time_ref,
               call_ref, put_ref):
    spot = spot_ref[...]
    strike = strike_ref[...]
    rate = rate_ref[...]
    vol = vol_ref[...]
    t = time_ref[...]

    sqrt_t = jnp.sqrt(t)
    d1 = (jnp.log(spot / strike) + (rate + 0.5 * vol * vol) * t) / (vol * sqrt_t)
    d2 = d1 - vol * sqrt_t
    disc = strike * jnp.exp(-rate * t)
    call_ref[...] = spot * _cnd(d1) - disc * _cnd(d2)
    put_ref[...] = disc * _cnd(-d2) - spot * _cnd(-d1)


@functools.partial(jax.jit, static_argnames=())
def blackscholes(spot, strike, rate, vol, time):
    """Price a batch of European options.

    All inputs: f32[n] with n a multiple of BS_BLOCK.
    Returns (call: f32[n], put: f32[n]).
    """
    n = spot.shape[0]
    if n % BS_BLOCK != 0:
        raise ValueError(f"n={n} must be a multiple of {BS_BLOCK}")
    grid = (n // BS_BLOCK,)
    spec = pl.BlockSpec((BS_BLOCK,), lambda i: (i,))
    return pl.pallas_call(
        _bs_kernel,
        grid=grid,
        in_specs=[spec] * 5,
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,
    )(spot, strike, rate, vol, time)
