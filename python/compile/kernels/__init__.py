"""L1 Pallas kernels for the parti-sim workload-synthesis pipeline.

Everything here runs at *build time only* (``make artifacts``); the Rust
coordinator executes the AOT-lowered HLO via PJRT and never imports Python.

uint64 math is used throughout the address generator, so x64 mode must be
enabled before any jax import downstream of this package.
"""

import jax

jax.config.update("jax_enable_x64", True)

from . import ref  # noqa: E402,F401
from .addrgen import addrgen, ADDRGEN_BLOCK, PARAMS_LEN  # noqa: E402,F401
from .blackscholes import blackscholes, BS_BLOCK  # noqa: E402,F401
from .stream_triad import stream_triad, TRIAD_BLOCK  # noqa: E402,F401
