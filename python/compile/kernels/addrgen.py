"""Pallas address-stream generator kernel (the trace-synthesis hot spot).

One invocation produces, for a single simulated core, a block-tiled stream of
``n`` memory operations: line-aligned addresses, a load/store flag, and the
compute-cycle gap preceding each operation. The knobs (working-set sizes,
stride, sharing fraction, ...) parameterise the PARSEC/STREAM-like behaviours
of Table 3 in the paper.

Tiling (§Perf / §Hardware-Adaptation in DESIGN.md): the grid iterates over
``n // ADDRGEN_BLOCK`` steps; each step materialises one block of the three
output streams entirely in VMEM (3 × 1024 lanes × ≤8 B = 24 KiB ≪ VMEM).
There is no matmul — this is a VPU-bound elementwise kernel — so the MXU is
idle by design. The kernel is lowered with ``interpret=True``: the CPU PJRT
backend cannot execute Mosaic custom-calls, and interpret mode folds the grid
into plain HLO that any backend runs. On a real TPU the 1-D iota below would
need to be a 2-D ``broadcasted_iota``; interpret mode accepts 1-D.

Parameter vector layout (uint64[PARAMS_LEN], shared with the Rust
re-implementation in ``rust/src/workload/generator.rs`` — keep in sync):

  [0] seed            [1] core_id        [2] offset (stream position)
  [3] private_base    [4] private_size   [5] shared_base
  [6] shared_size     [7] stride         [8] share_milli
  [9] random_milli   [10] line_bytes    [11] compute_base
 [12] compute_spread [13] store_milli   [14..15] reserved
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import SQUARES_KEY

ADDRGEN_BLOCK = 1024
PARAMS_LEN = 16

# Parameter indices (mirror of the table above).
P_SEED = 0
P_CORE_ID = 1
P_OFFSET = 2
P_PRIVATE_BASE = 3
P_PRIVATE_SIZE = 4
P_SHARED_BASE = 5
P_SHARED_SIZE = 6
P_STRIDE = 7
P_SHARE_MILLI = 8
P_RANDOM_MILLI = 9
P_LINE_BYTES = 10
P_COMPUTE_BASE = 11
P_COMPUTE_SPREAD = 12
P_STORE_MILLI = 13


def _squares32(ctr, key):
    """squares32 CBRNG round function — see ref.squares32_ref."""
    x = ctr * key
    y = x
    z = y + key
    x = x * x + y
    x = (x >> jnp.uint64(32)) | (x << jnp.uint64(32))
    x = x * x + z
    x = (x >> jnp.uint64(32)) | (x << jnp.uint64(32))
    x = x * x + y
    x = (x >> jnp.uint64(32)) | (x << jnp.uint64(32))
    x = x * x + z
    return (x >> jnp.uint64(32)).astype(jnp.uint32)


def _addrgen_kernel(params_ref, addr_ref, store_ref, gap_ref):
    """One grid step: synthesise ADDRGEN_BLOCK ops for the current block."""
    blk = pl.program_id(0)
    p = params_ref[...]
    key = jnp.uint64(SQUARES_KEY)

    seed = p[P_SEED]
    core_id = p[P_CORE_ID]
    offset = p[P_OFFSET]
    line_bytes = jnp.maximum(p[P_LINE_BYTES], jnp.uint64(1))
    private_lines = jnp.maximum(p[P_PRIVATE_SIZE] // line_bytes, jnp.uint64(1))
    shared_lines = jnp.maximum(p[P_SHARED_SIZE] // line_bytes, jnp.uint64(1))

    # Global stream index of each lane in this block.
    lane = jax.lax.iota(jnp.uint64, ADDRGEN_BLOCK)
    i = offset + blk.astype(jnp.uint64) * jnp.uint64(ADDRGEN_BLOCK) + lane

    base_ctr = seed ^ (core_id << jnp.uint64(40))
    ctr = base_ctr + i * jnp.uint64(4)
    r0 = _squares32(ctr, key)
    r1 = _squares32(ctr + jnp.uint64(1), key)
    r2 = _squares32(ctr + jnp.uint64(2), key)
    r3 = _squares32(ctr + jnp.uint64(3), key)

    # Sequential walk advances one line every 8 ops (sub-line spatial
    # locality: ~8 consecutive accesses land in one 64B line).
    seq_line = ((i >> jnp.uint64(3)) * p[P_STRIDE]) % private_lines
    rnd_line = r1.astype(jnp.uint64) % private_lines
    use_rnd = (r1 % jnp.uint32(1000)) < p[P_RANDOM_MILLI].astype(jnp.uint32)
    priv_line = jnp.where(use_rnd, rnd_line, seq_line)
    priv_addr = p[P_PRIVATE_BASE] + priv_line * line_bytes

    shared_line = r1.astype(jnp.uint64) % shared_lines
    shared_addr = p[P_SHARED_BASE] + shared_line * line_bytes

    use_shared = (r0 % jnp.uint32(1000)) < p[P_SHARE_MILLI].astype(jnp.uint32)
    addr_ref[...] = jnp.where(use_shared, shared_addr, priv_addr)

    store_ref[...] = (
        (r2 % jnp.uint32(1000)) < p[P_STORE_MILLI].astype(jnp.uint32)
    ).astype(jnp.uint32)

    spread = jnp.maximum(p[P_COMPUTE_SPREAD].astype(jnp.uint32), jnp.uint32(1))
    gap_ref[...] = (
        p[P_COMPUTE_BASE].astype(jnp.uint32) + r3 % spread
    ).astype(jnp.uint32)


@functools.partial(jax.jit, static_argnames=("n",))
def addrgen(params: jnp.ndarray, *, n: int = 16384):
    """Generate ``n`` trace ops for one core.

    params: uint64[PARAMS_LEN] (layout in module docstring).
    Returns (addr: uint64[n], is_store: uint32[n], gap_cycles: uint32[n]).
    ``n`` must be a multiple of ADDRGEN_BLOCK.
    """
    if n % ADDRGEN_BLOCK != 0:
        raise ValueError(f"n={n} must be a multiple of {ADDRGEN_BLOCK}")
    grid = (n // ADDRGEN_BLOCK,)
    return pl.pallas_call(
        _addrgen_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((PARAMS_LEN,), lambda i: (0,))],
        out_specs=[
            pl.BlockSpec((ADDRGEN_BLOCK,), lambda i: (i,)),
            pl.BlockSpec((ADDRGEN_BLOCK,), lambda i: (i,)),
            pl.BlockSpec((ADDRGEN_BLOCK,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.uint64),
            jax.ShapeDtypeStruct((n,), jnp.uint32),
            jax.ShapeDtypeStruct((n,), jnp.uint32),
        ],
        interpret=True,
    )(params)
