"""Pure-jnp reference oracles for the Pallas kernels.

These are the ground truth the Pallas implementations are tested against
(pytest + hypothesis in python/tests/). They are also what the L2 model
falls back to for shapes the kernels do not tile evenly.
"""

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# squares32: counter-based PRNG (Widynski, "Squares: A Fast Counter-Based
# RNG"). Deterministic, stateless, vectorises trivially -> ideal for
# reproducible address-stream synthesis on both the JAX and Rust sides.
# The Rust workload generator re-implements the identical function so that
# procedurally generated fallback traces match AOT-artifact traces bit-for-bit.
# ---------------------------------------------------------------------------

SQUARES_KEY = 0xC58EFD154CE32F6D


def squares32_ref(ctr: jnp.ndarray, key: int = SQUARES_KEY) -> jnp.ndarray:
    """32-bit output counter-based RNG. ctr: uint64 array -> uint32 array."""
    ctr = ctr.astype(jnp.uint64)
    key = jnp.uint64(key)
    x = ctr * key
    y = x
    z = y + key
    # round 1
    x = x * x + y
    x = (x >> jnp.uint64(32)) | (x << jnp.uint64(32))
    # round 2
    x = x * x + z
    x = (x >> jnp.uint64(32)) | (x << jnp.uint64(32))
    # round 3
    x = x * x + y
    x = (x >> jnp.uint64(32)) | (x << jnp.uint64(32))
    # round 4
    x = x * x + z
    return (x >> jnp.uint64(32)).astype(jnp.uint32)


# ---------------------------------------------------------------------------
# Address-stream synthesis.
#
# Each simulated core executes a stream of memory ops. The address stream is
# a mixture of:
#   * private sequential/strided accesses within the core's working set
#   * random accesses within the private working set
#   * accesses to a globally shared region (fraction `share_milli`/1000)
# matching the knobs that differentiate the PARSEC applications in Table 3.
# All parameters are integers (milli-fractions) so the kernel is pure uint
# math and bit-exact against the Rust re-implementation.
# ---------------------------------------------------------------------------


def addrgen_ref(
    core_id,
    n,
    *,
    seed,
    private_base,
    private_size,
    shared_base,
    shared_size,
    stride,
    share_milli,
    random_milli,
    line_bytes=64,
    compute_base=0,
    compute_spread=1,
    store_milli=300,
    offset=0,
):
    """Reference address-stream generator (mirror of the Pallas kernel in
    addrgen.py and of rust/src/workload/generator.rs — keep all three in
    sync).

    Returns (addrs: uint64[n], is_store: uint32[n], gap: uint32[n]).

    Per element i (counter = seed ^ (core_id<<40), stream position offset+i,
    counter stride 4):
      r0 -> selects shared vs private (r0 % 1000 < share_milli)
      r1 -> random offset source
      r2 -> store decision (r2 % 1000 < store_milli)
      r3 -> compute-cycle gap (compute_base + r3 % compute_spread)
    Private pattern: strided walk (i * stride) % private_lines for the
    sequential part, random within the working set when r1 % 1000 <
    random_milli. Shared pattern: random line in the shared region.
    Addresses are line-aligned.
    """
    i = jnp.arange(n, dtype=jnp.uint64) + jnp.uint64(offset)
    base_ctr = jnp.uint64(seed) ^ (jnp.uint64(core_id) << jnp.uint64(40))
    ctr = base_ctr + i * jnp.uint64(4)
    r0 = squares32_ref(ctr)
    r1 = squares32_ref(ctr + jnp.uint64(1))
    r2 = squares32_ref(ctr + jnp.uint64(2))
    r3 = squares32_ref(ctr + jnp.uint64(3))

    private_lines = jnp.uint64(max(private_size // line_bytes, 1))
    shared_lines = jnp.uint64(max(shared_size // line_bytes, 1))

    # One line per 8 sequential ops (spatial locality within a 64B line).
    seq_line = ((i >> jnp.uint64(3)) * jnp.uint64(stride)) % private_lines
    rnd_line = r1.astype(jnp.uint64) % private_lines
    use_rnd = (r1 % jnp.uint32(1000)) < jnp.uint32(random_milli)
    priv_line = jnp.where(use_rnd, rnd_line, seq_line)
    priv_addr = jnp.uint64(private_base) + priv_line * jnp.uint64(line_bytes)

    shared_line = r1.astype(jnp.uint64) % shared_lines
    shared_addr = jnp.uint64(shared_base) + shared_line * jnp.uint64(line_bytes)

    use_shared = (r0 % jnp.uint32(1000)) < jnp.uint32(share_milli)
    addr = jnp.where(use_shared, shared_addr, priv_addr)
    is_store = ((r2 % jnp.uint32(1000)) < jnp.uint32(store_milli)).astype(
        jnp.uint32
    )
    gap = (
        jnp.uint32(compute_base) + r3 % jnp.uint32(max(compute_spread, 1))
    ).astype(jnp.uint32)
    return addr, is_store, gap


# ---------------------------------------------------------------------------
# Black-Scholes (PARSEC blackscholes payload). European call/put prices.
# ---------------------------------------------------------------------------


def _cnd_ref(x):
    """Cumulative normal distribution, Abramowitz & Stegun 26.2.17 — the same
    polynomial PARSEC's blackscholes uses (keeps both sides comparable)."""
    a1, a2, a3, a4, a5 = (
        0.31938153,
        -0.356563782,
        1.781477937,
        -1.821255978,
        1.330274429,
    )
    l = jnp.abs(x)
    k = 1.0 / (1.0 + 0.2316419 * l)
    poly = k * (a1 + k * (a2 + k * (a3 + k * (a4 + k * a5))))
    w = 1.0 - 1.0 / jnp.sqrt(2.0 * jnp.pi) * jnp.exp(-l * l / 2.0) * poly
    return jnp.where(x < 0.0, 1.0 - w, w)


def blackscholes_ref(spot, strike, rate, vol, time):
    """Returns (call, put) prices, float32 arrays of the input shape."""
    sqrt_t = jnp.sqrt(time)
    d1 = (jnp.log(spot / strike) + (rate + 0.5 * vol * vol) * time) / (
        vol * sqrt_t
    )
    d2 = d1 - vol * sqrt_t
    disc = strike * jnp.exp(-rate * time)
    call = spot * _cnd_ref(d1) - disc * _cnd_ref(d2)
    put = disc * _cnd_ref(-d2) - spot * _cnd_ref(-d1)
    return call, put


# ---------------------------------------------------------------------------
# STREAM triad payload: a = b + scalar * c.
# ---------------------------------------------------------------------------


def stream_triad_ref(b, c, scalar):
    return b + scalar * c
