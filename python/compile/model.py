"""L2 — the workload-synthesis model (build-time JAX, calls kernels.*).

The paper evaluates parti-gem5 with ARM binaries (a bare-metal sort, a PARSEC
subset, STREAM). Our simulated cores execute *op traces*; this module is the
compute graph that synthesises those traces and the workloads' numeric
payloads. It is lowered once by ``aot.py`` into ``artifacts/*.hlo.txt`` and
executed from the Rust runtime via PJRT — Python never runs on the
simulation path.

Exported entry points (one HLO artifact each):

  workload_trace(params)            -> (addr u64[N], is_store u32[N], gap u32[N])
  blackscholes_payload(spot, ...)   -> (call f32[B], put f32[B])
  stream_payload(b, c, scalar)      -> a f32[B]

``option_inputs`` derives Black-Scholes option-parameter streams from the
same counter-based RNG, so the Rust side can regenerate identical inputs and
check functional end-to-end correctness of data passed through the simulated
coherent memory.
"""

import jax.numpy as jnp

from .kernels import (
    addrgen,
    blackscholes,
    stream_triad,
    ADDRGEN_BLOCK,
    PARAMS_LEN,
)
from .kernels.ref import addrgen_ref, squares32_ref

# Fixed artifact shapes (the Rust side slices / re-invokes as needed).
TRACE_N = 16384
PAYLOAD_B = 4096


def workload_trace(params: jnp.ndarray):
    """Synthesise TRACE_N ops for one core. params: uint64[PARAMS_LEN]."""
    addr, is_store, gap = addrgen(params, n=TRACE_N)
    return addr, is_store, gap


def blackscholes_payload(spot, strike, rate, vol, time):
    """Price PAYLOAD_B options (PARSEC blackscholes ground truth)."""
    return blackscholes(spot, strike, rate, vol, time)


def stream_payload(b, c, scalar):
    """STREAM triad ground truth."""
    return stream_triad(b, c, scalar)


def option_inputs(seed: int, n: int = PAYLOAD_B):
    """Deterministic option-parameter streams from squares32 (pure jnp).

    Used by aot.py to bake example inputs next to the artifacts and by the
    tests; the Rust side regenerates the identical streams (same CBRNG).
    """
    i = jnp.arange(n, dtype=jnp.uint64) + (jnp.uint64(seed) << jnp.uint64(20))

    def u(k):
        r = squares32_ref(i * jnp.uint64(5) + jnp.uint64(k))
        return r.astype(jnp.float32) / jnp.float32(2**32)

    spot = 5.0 + 95.0 * u(0)
    strike = 5.0 + 95.0 * u(1)
    rate = 0.01 + 0.09 * u(2)
    vol = 0.05 + 0.55 * u(3)
    time = 0.1 + 2.9 * u(4)
    return spot, strike, rate, vol, time


def trace_ref(params_dict, n: int = TRACE_N):
    """Pure-jnp oracle for workload_trace addresses (used by python/tests)."""
    addr, is_store, _gap = addrgen_ref(
        params_dict["core_id"],
        n,
        seed=params_dict["seed"],
        private_base=params_dict["private_base"],
        private_size=params_dict["private_size"],
        shared_base=params_dict["shared_base"],
        shared_size=params_dict["shared_size"],
        stride=params_dict["stride"],
        share_milli=params_dict["share_milli"],
        random_milli=params_dict["random_milli"],
        line_bytes=params_dict["line_bytes"],
    )
    return addr, is_store


__all__ = [
    "workload_trace",
    "blackscholes_payload",
    "stream_payload",
    "option_inputs",
    "trace_ref",
    "TRACE_N",
    "PAYLOAD_B",
    "ADDRGEN_BLOCK",
    "PARAMS_LEN",
]
