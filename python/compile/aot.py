"""AOT-lower the L2 model to HLO *text* artifacts for the Rust runtime.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the published ``xla`` 0.1.6 crate) rejects
(``proto.id() <= INT_MAX``). The HLO text parser reassigns ids, so text
round-trips cleanly. See /opt/xla-example/gen_hlo.py.

Artifacts (written to ``--outdir``, default ../artifacts):

  workload.hlo.txt      params u64[16]                     -> (addr u64[N], store u32[N], gap u32[N])
  blackscholes.hlo.txt  5 x f32[B]                          -> (call f32[B], put f32[B])
  stream.hlo.txt        b f32[B], c f32[B], scalar f32[1]   -> a f32[B]
  manifest.json         shapes + constants the Rust side asserts against

Usage: cd python && python -m compile.aot [--outdir ../artifacts] [--out ../artifacts/model.hlo.txt]
"""

import argparse
import json
import pathlib

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402
from .kernels import PARAMS_LEN  # noqa: E402


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_workload() -> str:
    spec = jax.ShapeDtypeStruct((PARAMS_LEN,), jnp.uint64)
    return to_hlo_text(jax.jit(model.workload_trace).lower(spec))


def lower_blackscholes() -> str:
    spec = jax.ShapeDtypeStruct((model.PAYLOAD_B,), jnp.float32)
    return to_hlo_text(
        jax.jit(model.blackscholes_payload).lower(spec, spec, spec, spec, spec)
    )


def lower_stream() -> str:
    vec = jax.ShapeDtypeStruct((model.PAYLOAD_B,), jnp.float32)
    scl = jax.ShapeDtypeStruct((1,), jnp.float32)
    return to_hlo_text(jax.jit(model.stream_payload).lower(vec, vec, scl))


ARTIFACTS = {
    "workload": lower_workload,
    "blackscholes": lower_blackscholes,
    "stream": lower_stream,
}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    # --out kept for Makefile compatibility: names the stamp artifact; all
    # artifacts are always emitted into its directory.
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    outdir = pathlib.Path(args.out).parent if args.out else pathlib.Path(args.outdir)
    outdir.mkdir(parents=True, exist_ok=True)

    manifest = {
        "params_len": PARAMS_LEN,
        "trace_n": model.TRACE_N,
        "payload_b": model.PAYLOAD_B,
        "artifacts": {},
    }
    for name, lower in ARTIFACTS.items():
        text = lower()
        path = outdir / f"{name}.hlo.txt"
        path.write_text(text)
        manifest["artifacts"][name] = {
            "file": path.name,
            "chars": len(text),
        }
        print(f"wrote {path} ({len(text)} chars)")

    (outdir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    if args.out:
        # Stamp file expected by the Makefile dependency rule.
        stamp = pathlib.Path(args.out)
        if stamp.name not in {f"{n}.hlo.txt" for n in ARTIFACTS}:
            stamp.write_text(
                "\n".join(f"{n}.hlo.txt" for n in ARTIFACTS) + "\n"
            )
    print(f"wrote {outdir / 'manifest.json'}")


if __name__ == "__main__":
    main()
