"""L2 model tests: trace synthesis composition, option-input determinism,
and the AOT lowering path (HLO text emission + shape manifest)."""

import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import model
from compile.aot import ARTIFACTS, to_hlo_text, lower_workload
from compile.kernels import PARAMS_LEN
from compile.kernels.ref import blackscholes_ref
from tests.test_kernel import make_params, ref_from_params


class TestWorkloadTrace:
    def test_shapes_and_dtypes(self):
        a, s, g = model.workload_trace(make_params())
        assert a.shape == (model.TRACE_N,) and a.dtype == jnp.uint64
        assert s.shape == (model.TRACE_N,) and s.dtype == jnp.uint32
        assert g.shape == (model.TRACE_N,) and g.dtype == jnp.uint32

    def test_matches_ref(self):
        p = make_params(core_id=3, share_milli=400)
        a, s, g = model.workload_trace(p)
        a_r, s_r, g_r = ref_from_params(p, model.TRACE_N)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(a_r))
        np.testing.assert_array_equal(np.asarray(s), np.asarray(s_r))
        np.testing.assert_array_equal(np.asarray(g), np.asarray(g_r))

    def test_trace_ref_wrapper(self):
        d = dict(
            core_id=1, seed=9, private_base=0x1000, private_size=4096,
            shared_base=0x200000, shared_size=65536, stride=2,
            share_milli=150, random_milli=100, line_bytes=64,
        )
        a, s = model.trace_ref(d, n=1024)
        assert a.shape == (1024,)
        assert np.asarray(a).min() >= 0x1000


class TestOptionInputs:
    def test_deterministic(self):
        a = model.option_inputs(seed=5)
        b = model.option_inputs(seed=5)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_seeds_differ(self):
        a = model.option_inputs(seed=5)
        b = model.option_inputs(seed=6)
        assert not np.array_equal(np.asarray(a[0]), np.asarray(b[0]))

    def test_ranges(self):
        spot, strike, rate, vol, t = map(
            np.asarray, model.option_inputs(seed=1)
        )
        assert spot.min() >= 5.0 and spot.max() <= 100.0
        assert rate.min() >= 0.01 and rate.max() <= 0.1
        assert vol.min() >= 0.05 and vol.max() <= 0.6
        assert t.min() >= 0.1 and t.max() <= 3.0

    def test_payload_pipeline(self):
        ins = model.option_inputs(seed=2)
        c_k, p_k = model.blackscholes_payload(*ins)
        c_r, p_r = blackscholes_ref(*ins)
        np.testing.assert_allclose(c_k, c_r, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(p_k, p_r, rtol=1e-5, atol=1e-5)


class TestAotLowering:
    def test_all_artifacts_lower_to_hlo_text(self):
        for name, lower in ARTIFACTS.items():
            text = lower()
            assert text.startswith("HloModule"), name
            assert "ENTRY" in text, name

    def test_workload_hlo_io_shapes(self):
        text = lower_workload()
        # One u64[16] parameter; tuple of (u64[N], u32[N], u32[N]) root.
        assert "u64[16]" in text
        assert f"u64[{model.TRACE_N}]" in text
        assert f"u32[{model.TRACE_N}]" in text

    def test_emission_writes_files(self, tmp_path, monkeypatch):
        import sys
        from compile import aot

        monkeypatch.setattr(
            sys, "argv", ["aot", "--outdir", str(tmp_path)]
        )
        aot.main()
        names = {p.name for p in tmp_path.iterdir()}
        assert {
            "workload.hlo.txt",
            "blackscholes.hlo.txt",
            "stream.hlo.txt",
            "manifest.json",
        } <= names
        manifest = json.loads((tmp_path / "manifest.json").read_text())
        assert manifest["trace_n"] == model.TRACE_N
        assert manifest["params_len"] == PARAMS_LEN
