"""Kernel-vs-reference correctness: the CORE signal for the L1 layer.

Each Pallas kernel (interpret=True) must match its pure-jnp oracle exactly
(integer kernels) or to f32 tolerance (float kernels), across a hypothesis
sweep of shapes and parameters.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile.kernels import (
    addrgen,
    blackscholes,
    stream_triad,
    ADDRGEN_BLOCK,
    BS_BLOCK,
    TRIAD_BLOCK,
    PARAMS_LEN,
)
from compile.kernels.addrgen import (
    P_SEED,
    P_CORE_ID,
    P_OFFSET,
    P_PRIVATE_BASE,
    P_PRIVATE_SIZE,
    P_SHARED_BASE,
    P_SHARED_SIZE,
    P_STRIDE,
    P_SHARE_MILLI,
    P_RANDOM_MILLI,
    P_LINE_BYTES,
    P_COMPUTE_BASE,
    P_COMPUTE_SPREAD,
    P_STORE_MILLI,
)
from compile.kernels.ref import (
    addrgen_ref,
    blackscholes_ref,
    stream_triad_ref,
    squares32_ref,
)


def make_params(
    *,
    seed=42,
    core_id=0,
    offset=0,
    private_base=0x1000_0000,
    private_size=64 * 1024,
    shared_base=0x8000_0000,
    shared_size=8 * 1024 * 1024,
    stride=1,
    share_milli=100,
    random_milli=200,
    line_bytes=64,
    compute_base=2,
    compute_spread=8,
    store_milli=300,
):
    p = np.zeros(PARAMS_LEN, dtype=np.uint64)
    p[P_SEED] = seed
    p[P_CORE_ID] = core_id
    p[P_OFFSET] = offset
    p[P_PRIVATE_BASE] = private_base
    p[P_PRIVATE_SIZE] = private_size
    p[P_SHARED_BASE] = shared_base
    p[P_SHARED_SIZE] = shared_size
    p[P_STRIDE] = stride
    p[P_SHARE_MILLI] = share_milli
    p[P_RANDOM_MILLI] = random_milli
    p[P_LINE_BYTES] = line_bytes
    p[P_COMPUTE_BASE] = compute_base
    p[P_COMPUTE_SPREAD] = compute_spread
    p[P_STORE_MILLI] = store_milli
    return jnp.asarray(p)


def ref_from_params(p, n):
    p = np.asarray(p)
    return addrgen_ref(
        int(p[P_CORE_ID]),
        n,
        seed=int(p[P_SEED]),
        private_base=int(p[P_PRIVATE_BASE]),
        private_size=int(p[P_PRIVATE_SIZE]),
        shared_base=int(p[P_SHARED_BASE]),
        shared_size=int(p[P_SHARED_SIZE]),
        stride=int(p[P_STRIDE]),
        share_milli=int(p[P_SHARE_MILLI]),
        random_milli=int(p[P_RANDOM_MILLI]),
        line_bytes=int(p[P_LINE_BYTES]),
        compute_base=int(p[P_COMPUTE_BASE]),
        compute_spread=int(p[P_COMPUTE_SPREAD]),
        store_milli=int(p[P_STORE_MILLI]),
        offset=int(p[P_OFFSET]),
    )


# ---------------------------------------------------------------------------
# squares32 sanity
# ---------------------------------------------------------------------------


class TestSquares32:
    def test_deterministic(self):
        c = jnp.arange(128, dtype=jnp.uint64)
        a = squares32_ref(c)
        b = squares32_ref(c)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_distribution_rough_uniformity(self):
        c = jnp.arange(1 << 16, dtype=jnp.uint64)
        r = np.asarray(squares32_ref(c))
        # mean of uint32 uniform is ~2^31; allow 1% slack
        assert abs(r.mean() - 2**31) < 0.01 * 2**32
        # no constant output
        assert len(np.unique(r)) > 60000

    def test_different_counters_differ(self):
        a = np.asarray(squares32_ref(jnp.uint64(1)))
        b = np.asarray(squares32_ref(jnp.uint64(2)))
        assert a != b

    def test_known_vector_stability(self):
        """Pinned goldens — keep in sync with
        rust/tests/artifact_parity.rs::squares32_matches_python_goldens."""
        c = jnp.asarray([0, 1, 2, 12345678901234, 2**63], dtype=jnp.uint64)
        r = [int(x) for x in np.asarray(squares32_ref(c))]
        assert r == [
            0x8352D815,
            0x4D645C71,
            0x5F664B34,
            0x837DF4DA,
            0x0BB1AB45,
        ], [hex(x) for x in r]


# ---------------------------------------------------------------------------
# addrgen kernel vs ref
# ---------------------------------------------------------------------------


class TestAddrgen:
    def test_matches_ref_default(self):
        p = make_params()
        a_k, s_k, g_k = addrgen(p, n=2 * ADDRGEN_BLOCK)
        a_r, s_r, g_r = ref_from_params(p, 2 * ADDRGEN_BLOCK)
        np.testing.assert_array_equal(np.asarray(a_k), np.asarray(a_r))
        np.testing.assert_array_equal(np.asarray(s_k), np.asarray(s_r))
        np.testing.assert_array_equal(np.asarray(g_k), np.asarray(g_r))

    def test_rejects_unaligned_n(self):
        with pytest.raises(ValueError):
            addrgen(make_params(), n=ADDRGEN_BLOCK + 1)

    def test_offset_continuation(self):
        """Two half-length calls with offset must equal one full call."""
        p0 = make_params(offset=0)
        p1 = make_params(offset=ADDRGEN_BLOCK)
        full_a, full_s, full_g = addrgen(p0, n=2 * ADDRGEN_BLOCK)
        a0, s0, g0 = addrgen(p0, n=ADDRGEN_BLOCK)
        a1, s1, g1 = addrgen(p1, n=ADDRGEN_BLOCK)
        np.testing.assert_array_equal(
            np.asarray(full_a), np.concatenate([a0, a1])
        )
        np.testing.assert_array_equal(
            np.asarray(full_g), np.concatenate([g0, g1])
        )

    def test_line_alignment(self):
        p = make_params(line_bytes=64)
        a, _, _ = addrgen(p, n=ADDRGEN_BLOCK)
        assert (np.asarray(a) % 64 == 0).all()

    def test_share_milli_zero_stays_private(self):
        p = make_params(share_milli=0)
        a, _, _ = addrgen(p, n=ADDRGEN_BLOCK)
        a = np.asarray(a)
        assert (a >= 0x1000_0000).all()
        assert (a < 0x1000_0000 + 64 * 1024).all()

    def test_share_milli_full_stays_shared(self):
        p = make_params(share_milli=1000)
        a, _, _ = addrgen(p, n=ADDRGEN_BLOCK)
        a = np.asarray(a)
        assert (a >= 0x8000_0000).all()

    def test_share_fraction_approximate(self):
        p = make_params(share_milli=250)
        a, _, _ = addrgen(p, n=8 * ADDRGEN_BLOCK)
        frac = (np.asarray(a) >= 0x8000_0000).mean()
        assert 0.2 < frac < 0.3

    def test_store_fraction_approximate(self):
        p = make_params(store_milli=300)
        _, s, _ = addrgen(p, n=8 * ADDRGEN_BLOCK)
        frac = np.asarray(s).mean()
        assert 0.25 < frac < 0.35

    def test_cores_get_disjoint_streams(self):
        a0, _, _ = addrgen(make_params(core_id=0), n=ADDRGEN_BLOCK)
        a1, _, _ = addrgen(make_params(core_id=1), n=ADDRGEN_BLOCK)
        assert not np.array_equal(np.asarray(a0), np.asarray(a1))

    def test_gap_bounds(self):
        p = make_params(compute_base=5, compute_spread=10)
        _, _, g = addrgen(p, n=ADDRGEN_BLOCK)
        g = np.asarray(g)
        assert (g >= 5).all() and (g < 15).all()

    @settings(max_examples=25, deadline=None)
    @given(
        core_id=st.integers(0, 127),
        seed=st.integers(0, 2**32 - 1),
        stride=st.integers(1, 64),
        share_milli=st.integers(0, 1000),
        random_milli=st.integers(0, 1000),
        private_size=st.sampled_from([4096, 65536, 1 << 20]),
        store_milli=st.integers(0, 1000),
    )
    def test_matches_ref_hypothesis(
        self, core_id, seed, stride, share_milli, random_milli,
        private_size, store_milli,
    ):
        p = make_params(
            core_id=core_id,
            seed=seed,
            stride=stride,
            share_milli=share_milli,
            random_milli=random_milli,
            private_size=private_size,
            store_milli=store_milli,
        )
        a_k, s_k, g_k = addrgen(p, n=ADDRGEN_BLOCK)
        a_r, s_r, g_r = ref_from_params(p, ADDRGEN_BLOCK)
        np.testing.assert_array_equal(np.asarray(a_k), np.asarray(a_r))
        np.testing.assert_array_equal(np.asarray(s_k), np.asarray(s_r))
        np.testing.assert_array_equal(np.asarray(g_k), np.asarray(g_r))


# ---------------------------------------------------------------------------
# blackscholes kernel vs ref
# ---------------------------------------------------------------------------


def _bs_inputs(n, seed=0):
    rng = np.random.default_rng(seed)
    spot = rng.uniform(5, 100, n).astype(np.float32)
    strike = rng.uniform(5, 100, n).astype(np.float32)
    rate = rng.uniform(0.01, 0.1, n).astype(np.float32)
    vol = rng.uniform(0.05, 0.6, n).astype(np.float32)
    t = rng.uniform(0.1, 3.0, n).astype(np.float32)
    return spot, strike, rate, vol, t


class TestBlackScholes:
    def test_matches_ref(self):
        ins = _bs_inputs(2 * BS_BLOCK)
        c_k, p_k = blackscholes(*map(jnp.asarray, ins))
        c_r, p_r = blackscholes_ref(*map(jnp.asarray, ins))
        np.testing.assert_allclose(c_k, c_r, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(p_k, p_r, rtol=1e-5, atol=1e-5)

    def test_rejects_unaligned(self):
        ins = _bs_inputs(BS_BLOCK + 3)
        with pytest.raises(ValueError):
            blackscholes(*map(jnp.asarray, ins))

    def test_put_call_parity(self):
        """C - P == S - K*exp(-rT) (model-independent identity)."""
        spot, strike, rate, vol, t = _bs_inputs(BS_BLOCK, seed=7)
        c, p = blackscholes(*map(jnp.asarray, (spot, strike, rate, vol, t)))
        lhs = np.asarray(c) - np.asarray(p)
        rhs = spot - strike * np.exp(-rate * t)
        np.testing.assert_allclose(lhs, rhs, rtol=2e-4, atol=2e-3)

    def test_prices_nonnegative(self):
        ins = _bs_inputs(BS_BLOCK, seed=3)
        c, p = blackscholes(*map(jnp.asarray, ins))
        assert (np.asarray(c) >= -1e-4).all()
        assert (np.asarray(p) >= -1e-4).all()

    def test_deep_itm_call_close_to_intrinsic(self):
        n = BS_BLOCK
        spot = np.full(n, 100.0, np.float32)
        strike = np.full(n, 1.0, np.float32)
        rate = np.full(n, 0.05, np.float32)
        vol = np.full(n, 0.2, np.float32)
        t = np.full(n, 0.5, np.float32)
        c, _ = blackscholes(*map(jnp.asarray, (spot, strike, rate, vol, t)))
        intrinsic = spot - strike * np.exp(-rate * t)
        np.testing.assert_allclose(np.asarray(c), intrinsic, rtol=1e-3)

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_matches_ref_hypothesis(self, seed):
        ins = _bs_inputs(BS_BLOCK, seed=seed)
        c_k, p_k = blackscholes(*map(jnp.asarray, ins))
        c_r, p_r = blackscholes_ref(*map(jnp.asarray, ins))
        np.testing.assert_allclose(c_k, c_r, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(p_k, p_r, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# stream triad kernel vs ref
# ---------------------------------------------------------------------------


class TestStreamTriad:
    def test_matches_ref(self):
        rng = np.random.default_rng(0)
        b = rng.standard_normal(2 * TRIAD_BLOCK).astype(np.float32)
        c = rng.standard_normal(2 * TRIAD_BLOCK).astype(np.float32)
        s = np.asarray([3.0], np.float32)
        a_k = stream_triad(jnp.asarray(b), jnp.asarray(c), jnp.asarray(s))
        a_r = stream_triad_ref(b, c, 3.0)
        # interpret-mode Pallas may contract b + s*c into an FMA
        np.testing.assert_allclose(np.asarray(a_k), a_r, rtol=1e-4, atol=1e-6)

    def test_rejects_unaligned(self):
        b = jnp.zeros(TRIAD_BLOCK + 1, jnp.float32)
        with pytest.raises(ValueError):
            stream_triad(b, b, jnp.zeros(1, jnp.float32))

    def test_zero_scalar_copies_b(self):
        rng = np.random.default_rng(1)
        b = rng.standard_normal(TRIAD_BLOCK).astype(np.float32)
        c = rng.standard_normal(TRIAD_BLOCK).astype(np.float32)
        a = stream_triad(
            jnp.asarray(b), jnp.asarray(c), jnp.zeros(1, jnp.float32)
        )
        np.testing.assert_array_equal(np.asarray(a), b)

    @settings(max_examples=20, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        scalar=st.floats(-100, 100, width=32, allow_nan=False),
        blocks=st.integers(1, 3),
    )
    def test_matches_ref_hypothesis(self, seed, scalar, blocks):
        rng = np.random.default_rng(seed)
        n = blocks * TRIAD_BLOCK
        b = rng.standard_normal(n).astype(np.float32)
        c = rng.standard_normal(n).astype(np.float32)
        a_k = stream_triad(
            jnp.asarray(b),
            jnp.asarray(c),
            jnp.asarray([scalar], dtype=jnp.float32),
        )
        a_r = stream_triad_ref(b, c, np.float32(scalar))
        np.testing.assert_allclose(np.asarray(a_k), a_r, rtol=1e-5, atol=1e-5)
