import sys, pathlib

# Make `compile.*` importable when pytest runs from the repository root.
sys.path.insert(0, str(pathlib.Path(__file__).parent))
